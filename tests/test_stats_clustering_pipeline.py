"""Communication mechanism (§4.1), clustering (§4.3), pipelining (§4.4)."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import clustering, pipeline as pipe
from repro.core.stats import StatsCollector, local_key_histogram


class TestStatsCollector:
    def test_idempotent_speculative_attempts(self):
        """Paper §6: one entry per task id regardless of attempts."""
        c = StatsCollector(num_clusters=4, num_map_tasks=2)
        c.report(0, [1, 0, 2, 0], attempt_id=0)
        c.report(0, [1, 0, 2, 0], attempt_id=1)  # speculative re-execution
        c.report(1, [0, 3, 0, 1])
        assert c.complete
        assert c.duplicate_reports == 1
        np.testing.assert_allclose(c.aggregate(), [1, 3, 2, 1])

    def test_failed_attempts_discarded(self):
        c = StatsCollector(num_clusters=2, num_map_tasks=1)
        c.report(0, [9, 9], success=False)
        assert not c.complete
        c.report(0, [1, 2], success=True)
        assert c.complete
        np.testing.assert_allclose(c.aggregate(), [1, 2])

    def test_incomplete_until_all_tasks(self):
        c = StatsCollector(num_clusters=2, num_map_tasks=3)
        c.report(0, [1, 0])
        c.report(2, [0, 1])
        assert not c.complete


def test_local_histogram_matches_numpy(rng):
    ids = jnp.asarray(rng.integers(0, 32, 500), jnp.int32)
    h = local_key_histogram(ids, 32)
    np.testing.assert_allclose(h, np.bincount(np.asarray(ids), minlength=32))


class TestClustering:
    @given(st.integers(1, 64), st.integers(1, 2000))
    @settings(max_examples=50, deadline=None)
    def test_cluster_ids_in_range(self, n_target, n_keys):
        hashes = np.arange(n_keys) * 2654435761 % (2 ** 31)
        cids = clustering.cluster_ids_for_keys(hashes, n_target)
        assert cids.min() >= 0 and cids.max() < n_target

    def test_cluster_loads_exact(self, rng):
        """vs Gufler et al.: cluster loads are exact sums (paper §7)."""
        loads = rng.random(100)
        cids = clustering.cluster_ids_for_keys(np.arange(100), 10)
        cl = clustering.cluster_loads(loads, cids, 10)
        np.testing.assert_allclose(cl.sum(), loads.sum())

    def test_network_cost_formula(self):
        """§4.3: total <= 4n(4M + t + r) bytes."""
        c = clustering.network_cost_bytes(80, 240, 8, 30)
        assert c.total <= 4 * 240 * (4 * 80 + 8 + 30)
        assert c.collect_total == 16 * 80 * 240
        # paper Fig 11: < 2 MB at experiment scale
        assert c.total < 2 * 2 ** 20

    def test_recommended_clusters_6_to_16x(self):
        n = clustering.recommended_num_clusters(30)
        assert 6 * 30 <= n <= 16 * 30


class TestPipeline:
    def test_pipelined_never_slower_than_sequential(self, rng):
        for _ in range(20):
            n = rng.integers(2, 30)
            ph = pipe.PhaseTimes(rng.random(n), rng.random(n), rng.random(n))
            seq = pipe.run_sequential(ph)
            par = pipe.run_pipelined(ph, order=pipe.plan_order(rng.random(n)))
            assert par.finish_time <= seq.finish_time + 1e-9

    def test_increasing_order_minimises_delays(self, rng):
        """§4.4: increasing-load order gives the smallest sort/run delay."""
        loads = rng.random(16) * 10
        ph = pipe.PhaseTimes(loads * 0.3, loads * 0.2, loads * 0.5)
        inc = pipe.run_pipelined(ph, order=pipe.plan_order(loads, "increasing"))
        dec = pipe.run_pipelined(ph, order=pipe.plan_order(loads, "decreasing"))
        assert inc.sort_delay <= dec.sort_delay + 1e-9
        assert inc.run_delay <= dec.run_delay + 1e-9

    @given(st.integers(1, 50), st.integers(1, 10), st.integers(0, 5))
    @settings(max_examples=50, deadline=None)
    def test_chunks_partition_all_ops(self, n, k, seed):
        rng = np.random.default_rng(seed)
        loads = rng.random(n)
        chunks = pipe.plan_chunks(loads, k)
        got = np.sort(np.concatenate(chunks))
        assert np.array_equal(got, np.arange(n))
        assert len(chunks) <= max(1, min(k, n))
