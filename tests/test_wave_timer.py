"""The ``kernels/wave_timer`` subsystem (ISSUE 5 tentpole).

* interpret-mode tick kernel: monotone stamps, word-format round trip;
* calibration: ticks↔seconds round trip, host-bracketed ``calibrate``;
* ``ops.read_ticks`` inside jitted / shard_map programs (per-shard
  stamps, ordering by data dependency);
* CPU fallback identity: with no tick source the measured executor
  degrades to the host-fenced path built on ``shard_ready_seconds``;
* bit-identity: overlapped-measured outputs == unmeasured == sequential
  (vmap) reference — stamps and barriers are value identities.

Mesh tests skip below 8 host devices (CI sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""

import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import mesh_timing as mt
from repro.core.mapreduce import MapReduceConfig, MapReduceJob
from repro.kernels.wave_timer import calibration as cal
from repro.kernels.wave_timer import ops as wt_ops
from repro.kernels.wave_timer import ref as wt_ref
from repro.kernels.wave_timer import wave_timer as wt


def _mesh(m):
    from jax.sharding import Mesh

    if len(jax.devices()) < m:
        pytest.skip("needs XLA_FLAGS=--xla_force_host_platform_device_count=8")
    return Mesh(np.asarray(jax.devices()[:m]), ("mr_slots",))


def _batch(seed, m, K=512, key_mod=503):
    rng = np.random.default_rng(seed)
    keys = (rng.zipf(1.25, size=(m, K)) % key_mod).astype(np.int32)
    return (jnp.asarray(keys), jnp.asarray(np.ones((m, K, 4), np.float32)),
            jnp.asarray(np.ones((m, K), bool)))


# ---------------------------------------------------------------------------
# Word format (ref oracle).
# ---------------------------------------------------------------------------


class TestTickWords:
    def test_split_combine_round_trip(self):
        vals = np.asarray([0, 1, 2**31, 2**32 - 1, 2**32, 2**40 + 12345,
                           time.perf_counter_ns()], np.int64)
        words = wt_ref.split_ticks(vals)
        assert words.shape == vals.shape + (2,)
        assert words.dtype == np.uint32
        back = wt_ref.combine_ticks(words)
        assert np.array_equal(back, vals)

    def test_combine_validates_trailing_axis(self):
        with pytest.raises(ValueError):
            wt_ref.combine_ticks(np.zeros((3, 4), np.uint32))

    def test_read_ticks_ref_is_monotone(self):
        a = wt_ref.combine_ticks(wt_ref.read_ticks_ref())
        b = wt_ref.combine_ticks(wt_ref.read_ticks_ref())
        assert b >= a > 0


# ---------------------------------------------------------------------------
# The interpret-mode Pallas kernel.
# ---------------------------------------------------------------------------


class TestInterpretKernel:
    def test_interpret_ticks_monotone(self):
        """Two sequential kernel reads advance (the perf_counter body)."""
        t1 = wt_ref.combine_ticks(np.asarray(jax.device_get(
            wt.read_ticks_pallas(jnp.float32(1.0), interpret=True))))
        time.sleep(1e-3)
        t2 = wt_ref.combine_ticks(np.asarray(jax.device_get(
            wt.read_ticks_pallas(jnp.float32(2.0), interpret=True))))
        assert int(t2) > int(t1) > 0

    def test_interpret_tick_interval_tracks_wall_clock(self):
        t1 = int(wt_ref.combine_ticks(np.asarray(jax.device_get(
            wt.read_ticks_pallas(jnp.float32(1.0), interpret=True)))))
        time.sleep(0.05)
        t2 = int(wt_ref.combine_ticks(np.asarray(jax.device_get(
            wt.read_ticks_pallas(jnp.float32(2.0), interpret=True)))))
        # host-ns ticks: 50 ms sleep is >= 4e7 ticks (loose lower bound)
        assert t2 - t1 >= 4e7

    def test_compiled_mode_requires_device_counter(self):
        if wt.device_tick_primitive() is not None:
            pytest.skip("toolchain exposes a device counter")
        with pytest.raises(RuntimeError):
            wt.read_ticks_pallas(jnp.float32(0.0), interpret=False)


# ---------------------------------------------------------------------------
# Calibration.
# ---------------------------------------------------------------------------


class TestCalibration:
    def test_round_trip(self):
        c = cal.TickCalibration(2.5e-9, source="test")
        secs = np.asarray([0.0, 1e-6, 3.2e-3, 1.5])
        back = c.ticks_to_seconds(c.seconds_to_ticks(secs))
        assert np.allclose(back, secs, rtol=0, atol=3e-9)

    def test_host_ns_unit_is_exact(self):
        assert cal.HOST_NS.seconds_per_tick == 1e-9
        assert cal.HOST_NS.ticks_to_seconds(1_000_000_000) == pytest.approx(1.0)

    def test_validates_scale(self):
        for bad in (0.0, -1e-9, float("nan"), float("inf")):
            with pytest.raises(ValueError):
                cal.TickCalibration(bad)

    def test_calibrate_host_counter_recovers_ns_scale(self):
        """Bracketing the host ns counter must land near 1e-9 s/tick.
        Very loose bounds: the container's scheduler can stretch any one
        sleep, but the median over repeats stays the right magnitude."""
        c = cal.calibrate(
            lambda: int(wt_ref.combine_ticks(wt_ref.read_ticks_ref())),
            sleep_seconds=0.02, repeats=3)
        assert 2e-10 < c.seconds_per_tick < 5e-9

    def test_calibrate_rejects_frozen_counter(self):
        with pytest.raises(RuntimeError):
            cal.calibrate(lambda: 42, sleep_seconds=0.0, repeats=2)

    def test_ops_tick_calibration_on_cpu_is_host_ns(self):
        assert wt_ops.backend() == "callback"    # this container is CPU
        assert wt_ops.tick_calibration() is cal.HOST_NS


# ---------------------------------------------------------------------------
# The jit-safe op.
# ---------------------------------------------------------------------------


class TestReadTicksOp:
    def test_backend_resolution_and_force(self):
        assert wt_ops.available()
        with wt_ops.force_backend("none"):
            assert wt_ops.backend() == "none"
            assert not wt_ops.available()
            with pytest.raises(RuntimeError):
                wt_ops.read_ticks(jnp.float32(0.0))
        assert wt_ops.available()                # restored on exit
        with pytest.raises(ValueError):
            wt_ops.force_backend("warp-core")

    def test_stamp_through_is_value_identity(self):
        x = jnp.asarray(np.random.default_rng(0).standard_normal((64, 8)),
                        jnp.float32)
        y, _ = jax.jit(lambda a: wt_ops.stamp_through(a))(x)
        assert np.array_equal(np.asarray(x), np.asarray(y))
        ids = jnp.arange(-5, 11, dtype=jnp.int32)
        out, _ = jax.jit(lambda a: wt_ops.stamp_through(a, jnp.float32(3)))(ids)
        assert np.array_equal(np.asarray(ids), np.asarray(out))

    def test_stamp_through_brackets_compute(self):
        """Two pass-through stamps around a computation must bracket it:
        the second consumes the compute's output, the first produces the
        compute's input — true buffer deps the scheduler cannot undo
        (anchoring alone, or optimization_barrier, demonstrably can)."""

        @jax.jit
        def prog(x):
            x, t0 = wt_ops.stamp_through(x)
            y = jnp.tanh(x @ x.T)
            for _ in range(4):
                y = jnp.tanh(y @ y)
            y, t1 = wt_ops.stamp_through(y)
            return y, t0, t1

        for _ in range(3):                   # steady state, not just trace run
            _, w0, w1 = prog(jnp.ones((128, 128)))
        a = int(wt_ref.combine_ticks(np.asarray(jax.device_get(w0))))
        b = int(wt_ref.combine_ticks(np.asarray(jax.device_get(w1))))
        assert b >= a > 0

    def test_per_shard_stamps_under_shard_map(self):
        from jax.sharding import PartitionSpec as P

        from repro import compat

        m = 8
        mesh = _mesh(m)

        def body(x):
            x, t0 = wt_ops.stamp_through(x)
            y = jnp.tanh(x @ x.T)
            y, t1 = wt_ops.stamp_through(y)
            return y, jnp.stack([t0, t1])[None]

        fn = jax.jit(compat.shard_map(
            body, mesh=mesh, in_specs=(P("mr_slots", None),),
            out_specs=(P("mr_slots", None), P("mr_slots", None))))
        for _ in range(2):
            _, words = fn(jnp.ones((m * 32, 32)))
        ticks = wt_ref.combine_ticks(
            np.asarray(jax.device_get(words)).reshape(m, 2, 2))
        assert (ticks[:, 1] >= ticks[:, 0]).all()   # per-shard monotone
        assert (ticks > 0).all()


# ---------------------------------------------------------------------------
# Executor integration: fallback identity + bit-identity.
# ---------------------------------------------------------------------------


class TestMeasuredExecutorIntegration:
    m = 8

    def _jobs(self, mesh, **kw):
        meas = MapReduceJob(lambda s: s, MapReduceConfig(
            num_slots=self.m, num_clusters=24, scheduler="bss",
            pipeline_chunks=3, estimate_speeds=True, **kw),
            backend="shard_map", mesh=mesh)
        return meas

    def test_cpu_fallback_uses_shard_ready_seconds(self, monkeypatch):
        """With no tick source the measured executor must degrade to the
        fenced path built on shard_ready_seconds (the documented
        fallback), with bit-identical outputs."""
        mesh = _mesh(self.m)
        calls = []
        real = mt.shard_ready_seconds

        def spy(outputs, num_slots, t0):
            calls.append(num_slots)
            return real(outputs, num_slots, t0)

        monkeypatch.setattr(mt, "shard_ready_seconds", spy)
        ref = MapReduceJob(lambda s: s, MapReduceConfig(
            num_slots=self.m, num_clusters=24, scheduler="bss",
            pipeline_chunks=3), backend="vmap")
        with wt_ops.force_backend("none"):
            job = self._jobs(mesh)
            b = _batch(0, self.m)
            r, v = job.run(b), ref.run(b)
        assert calls, "fenced fallback never consulted shard_ready_seconds"
        assert job.last_wave_timings is not None
        assert job.last_wave_timings.seconds.shape[0] == self.m
        assert np.array_equal(np.asarray(r.values), np.asarray(v.values))
        assert np.array_equal(np.asarray(r.counts), np.asarray(v.counts))

    def test_tick_path_does_not_touch_host_fences(self, monkeypatch):
        mesh = _mesh(self.m)

        def boom(*a, **k):                       # pragma: no cover - guard
            raise AssertionError("tick path must not host-fence")

        monkeypatch.setattr(mt, "shard_ready_seconds", boom)
        job = self._jobs(mesh)
        job.run(_batch(0, self.m))
        assert job.last_wave_timings is not None
        assert job.last_wave_timings.valid

    def test_overlapped_measured_bit_identical_to_sequential(self):
        """ISSUE 5 acceptance: overlapped-measured outputs are bit-equal
        to the Hadoop-style sequential phase B AND the unmeasured
        overlapped path on the same batches."""
        mesh = _mesh(self.m)
        measured = self._jobs(mesh)
        unmeasured = MapReduceJob(lambda s: s, MapReduceConfig(
            num_slots=self.m, num_clusters=24, scheduler="bss",
            pipeline_chunks=3), backend="shard_map", mesh=mesh)
        sequential = MapReduceJob(lambda s: s, MapReduceConfig(
            num_slots=self.m, num_clusters=24, scheduler="bss",
            pipelined=False), backend="vmap")
        for i in range(2):
            b = _batch(i, self.m)
            r_m, r_u, r_s = measured.run(b), unmeasured.run(b), sequential.run(b)
            assert measured.last_wave_timings is not None
            for other in (r_u, r_s):
                assert np.array_equal(np.asarray(r_m.values),
                                      np.asarray(other.values))
                assert np.array_equal(np.asarray(r_m.counts),
                                      np.asarray(other.counts))

    def test_ticks_buffer_shape_matches_plan_waves(self):
        mesh = _mesh(self.m)
        job = self._jobs(mesh)
        job.run(_batch(0, self.m))
        t = job.last_wave_timings
        assert t.seconds.shape[0] == self.m
        assert t.seconds.shape[1] >= 1
        assert (t.seconds >= 0).all()
