"""Arch smoke tests: every assigned architecture's reduced twin runs one
forward/train step with finite outputs, plus decode-parity integration."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_smoke
from repro.models.model import forward, init_cache, init_model, lm_loss
from repro.nn import layers as L

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, B=2, T=16):
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab)
    extra = None
    if cfg.n_patches:
        extra = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_patches, cfg.d_model))
    if cfg.enc_dec:
        extra = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.enc_len, cfg.d_model))
    return toks, extra


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_grad(arch):
    cfg = get_smoke(arch)
    params, _ = L.split(init_model(KEY, cfg))
    toks, extra = _inputs(cfg)
    out = forward(params, cfg, tokens=toks, extra_embed=extra, mode="train")
    total = (cfg.n_patches or 0) + toks.shape[1]
    assert out.logits.shape == (2, total, cfg.vocab)
    assert bool(jnp.isfinite(out.logits).all()), f"{arch}: NaN logits"

    def loss_fn(p):
        o = forward(p, cfg, tokens=toks, extra_embed=extra, mode="train")
        l = lm_loss(o.logits[:, -toks.shape[1]:], toks)
        if o.stats and "aux_loss" in o.stats:
            l = l + o.stats["aux_loss"]
        return l

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    assert jax.tree.all(jax.tree.map(
        lambda g: bool(jnp.isfinite(g).all()), grads)), f"{arch}: NaN grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_decode_parity(arch):
    """prefill(0..P) + decode steps == full causal forward (serve_step)."""
    if arch == "qwen2_vl_7b":
        pytest.skip("vlm decode continues after patches; covered separately")
    cfg = get_smoke(arch)
    params, _ = L.split(init_model(KEY, cfg))
    B, T, P = 2, 12, 8
    toks, extra = _inputs(cfg, B, T)
    full = forward(params, cfg, tokens=toks, extra_embed=extra,
                   mode="train").logits
    cache = init_cache(cfg, B, T, dtype=jnp.float32)
    o = forward(params, cfg, tokens=toks[:, :P], extra_embed=extra,
                mode="prefill", cache=cache, cache_pos=jnp.int32(0))
    logits = [o.logits]
    cache = o.cache
    for t in range(P, T):
        o = forward(params, cfg, tokens=toks[:, t:t + 1], mode="decode",
                    cache=cache, cache_pos=jnp.int32(t))
        cache = o.cache
        logits.append(o.logits)
    inc = jnp.concatenate(logits, axis=1)
    err = float(jnp.abs(full - inc).max())
    assert err < 5e-2, f"{arch}: decode parity err {err}"


def test_vector_cache_pos_matches_scalar():
    """Per-lane decode positions (continuous batching) == scalar path."""
    cfg = get_smoke("llama3-8b")
    params, _ = L.split(init_model(KEY, cfg))
    B, T, P = 2, 12, 8
    toks, _ = _inputs(cfg, B, T)
    cache = init_cache(cfg, B, T, dtype=jnp.float32)
    o = forward(params, cfg, tokens=toks[:, :P], mode="prefill",
                cache=cache, cache_pos=jnp.int32(0))
    cache_s, cache_v = o.cache, o.cache
    for t in range(P, T):
        os_ = forward(params, cfg, tokens=toks[:, t:t + 1], mode="decode",
                      cache=cache_s, cache_pos=jnp.int32(t))
        ov = forward(params, cfg, tokens=toks[:, t:t + 1], mode="decode",
                     cache=cache_v,
                     cache_pos=jnp.full((B,), t, jnp.int32))
        cache_s, cache_v = os_.cache, ov.cache
        np.testing.assert_allclose(os_.logits, ov.logits, atol=1e-5)


def test_train_step_reduces_loss():
    """A few optimizer steps on a tiny model reduce the loss (e2e)."""
    from repro.launch.mesh import single_device_mesh
    from repro.launch.steps import build_train_step
    from repro.models.config import Shape
    from repro.train.optim import OptConfig, init_opt

    cfg = get_smoke("smollm-360m")
    mesh = single_device_mesh()
    shape = Shape("t", "train", 32, 4)
    step, _ = build_train_step(cfg, mesh, shape,
                               opt_cfg=OptConfig(lr=5e-3, warmup_steps=1,
                                                 decay_steps=100))
    params, _ = L.split(init_model(KEY, cfg))
    opt = init_opt(params, OptConfig())
    toks = jax.random.randint(jax.random.PRNGKey(3), (4, 32), 0, cfg.vocab)
    batch = {"tokens": toks}
    losses = []
    for _ in range(8):
        params, opt, metrics = step(params, opt, batch, None)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses


def test_microbatched_step_matches_single():
    """Gradient accumulation is loss-equivalent to the monolithic step."""
    from repro.launch.mesh import single_device_mesh
    from repro.launch.steps import build_train_step
    from repro.models.config import Shape
    from repro.train.optim import OptConfig, init_opt

    cfg = get_smoke("llama3-8b")
    mesh = single_device_mesh()
    shape = Shape("t", "train", 16, 4)
    ocfg = OptConfig(lr=1e-3)
    s1, _ = build_train_step(cfg, mesh, shape, opt_cfg=ocfg, microbatches=1)
    s2, _ = build_train_step(cfg, mesh, shape, opt_cfg=ocfg, microbatches=2)
    params, _ = L.split(init_model(KEY, cfg))
    opt = init_opt(params, ocfg)
    toks = jax.random.randint(jax.random.PRNGKey(3), (4, 16), 0, cfg.vocab)
    p1, _, m1 = s1(params, opt, {"tokens": toks}, None)
    params2, _ = L.split(init_model(KEY, cfg))
    opt2 = init_opt(params2, ocfg)
    p2, _, m2 = s2(params2, opt2, {"tokens": toks}, None)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(a, b, atol=2e-5)
