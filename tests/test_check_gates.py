"""Unit tests for the consolidated CI gate checker (benchmarks/check.py).

The gates themselves run in CI against real bench JSON; here we pin the
*checker's* contract — the assertion helper's failure message carries
gate name, threshold, and actual value; each gate accepts a passing
report and rejects each individually-broken field; the CLI exits
non-zero on failure and zero on success.
"""

import copy
import json
import sys
import pathlib

import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks import check  # noqa: E402


class TestRequire:
    def test_pass_is_silent(self):
        check.require("g", True, "x >= 1", 2)

    def test_failure_message_names_gate_threshold_actual(self):
        with pytest.raises(check.GateFailure) as ei:
            check.require("elastic", False, "replayed <= remaining", 7)
        msg = str(ei.value)
        assert "[gate elastic]" in msg
        assert "replayed <= remaining" in msg
        assert "7" in msg


GOOD_ELASTIC = {
    "dead_at_start": {"bit_identical": True, "dead_slot_load": 0.0},
    "die_mid_wave": {"bit_identical": True, "num_waves": 4,
                     "checkpoint_wave": 2, "replayed_waves": 2,
                     "replay_bound_ok": True,
                     "replay_dead_slot_load": 0.0},
    "resizes": {"after_8to6_reason": "ok", "after_6to8_reason": "ok",
                "no_cold_after_resize": True, "reprojections": 2,
                "outputs_6_match": True, "outputs_8_bit_identical": True},
    "bit_identical": True,
    "dead_load_total": 0.0,
}

GOOD_REUSE = {
    "bit_identical": True, "stationary_replans": 1, "drift_replans": 2,
    "replan_rate": 0.1, "steady_state_seconds": 0.01,
    "always_replan_seconds": 0.05, "speedup": 5.0,
}


def _write(tmp_path, payload):
    p = tmp_path / "r.json"
    p.write_text(json.dumps(payload))
    return str(p)


class TestElasticGate:
    def test_good_report_passes(self, tmp_path, capsys):
        check.gate_elastic(_write(tmp_path, GOOD_ELASTIC))
        assert "reprojections=2" in capsys.readouterr().out

    @pytest.mark.parametrize("mutate", [
        lambda r: r["dead_at_start"].update(bit_identical=False),
        lambda r: r["dead_at_start"].update(dead_slot_load=3.0),
        lambda r: r["die_mid_wave"].update(replay_bound_ok=False),
        lambda r: r["die_mid_wave"].update(replay_dead_slot_load=1.0),
        lambda r: r["resizes"].update(no_cold_after_resize=False),
        lambda r: r["resizes"].update(reprojections=1),
        lambda r: r["resizes"].update(outputs_6_match=False),
    ])
    def test_each_broken_field_fails(self, tmp_path, mutate):
        r = copy.deepcopy(GOOD_ELASTIC)
        mutate(r)
        # keep the roll-up flag consistent with the scenario flags
        r["bit_identical"] = (r["dead_at_start"]["bit_identical"]
                              and r["die_mid_wave"]["bit_identical"]
                              and r["resizes"]["outputs_8_bit_identical"])
        with pytest.raises(check.GateFailure):
            check.gate_elastic(_write(tmp_path, r))


class TestReuseGate:
    def test_good_report_passes(self, tmp_path):
        check.gate_reuse(_write(tmp_path, GOOD_REUSE))

    @pytest.mark.parametrize("field,value", [
        ("bit_identical", False),
        ("stationary_replans", 2),
        ("drift_replans", 0),
    ])
    def test_thresholds(self, tmp_path, field, value):
        r = dict(GOOD_REUSE, **{field: value})
        with pytest.raises(check.GateFailure):
            check.gate_reuse(_write(tmp_path, r))


GOOD_MULTIJOB = {
    "fifo": {"order": ["bulk", "urgent"], "weighted_completion_s": 0.4},
    "wspt": {"order": ["urgent", "bulk"], "weighted_completion_s": 0.15},
    "improvement": 0.62,
    "bit_identical": True,
    "coschedule_overlap": 1.0,
    "cache": {"tenants": 2, "collisions": 0},
}


class TestMultijobGate:
    def test_good_report_passes(self, tmp_path, capsys):
        check.gate_multijob(_write(tmp_path, GOOD_MULTIJOB))
        assert "collisions=0" in capsys.readouterr().out

    @pytest.mark.parametrize("mutate", [
        lambda r: r.update(improvement=0.1),
        lambda r: r.update(bit_identical=False),
        lambda r: r["cache"].update(collisions=1),
        lambda r: r["cache"].update(tenants=1),
        lambda r: r["wspt"].update(order=["bulk", "urgent"]),
    ])
    def test_each_broken_field_fails(self, tmp_path, mutate):
        r = copy.deepcopy(GOOD_MULTIJOB)
        mutate(r)
        with pytest.raises(check.GateFailure):
            check.gate_multijob(_write(tmp_path, r))


GOOD_SHUFFLE = {
    "uncoded": {"shuffle_bytes": 1_000_000, "shuffle_rows": 25_000,
                "shuffle_pairs": 28_000, "wall_seconds": 0.04},
    "coded": {"shuffle_bytes": 510_000, "shuffle_rows": 12_700,
              "shuffle_pairs": 28_000, "replication_bytes": 1_100_000,
              "wall_seconds": 0.17},
    "bytes_reduction": 1.96,
    "bit_identical": True,
    "wall_ratio": 4.3,
    "wall_ok": True,
    "quantized": {"uncoded_bytes": 260_000, "coded_bytes": 300_000,
                  "bit_identical": True, "exact": False},
}


class TestShuffleVolumeGate:
    def test_good_report_passes(self, tmp_path, capsys):
        check.gate_shuffle_volume(_write(tmp_path, GOOD_SHUFFLE))
        assert "1.96x" in capsys.readouterr().out

    @pytest.mark.parametrize("mutate", [
        lambda r: r.update(bit_identical=False),
        lambda r: r.update(bytes_reduction=1.2),
        lambda r: r.update(wall_ok=False),
        lambda r: r["coded"].update(replication_bytes=0),
        lambda r: r["quantized"].update(bit_identical=False),
    ])
    def test_each_broken_field_fails(self, tmp_path, mutate):
        r = copy.deepcopy(GOOD_SHUFFLE)
        mutate(r)
        with pytest.raises(check.GateFailure):
            check.gate_shuffle_volume(_write(tmp_path, r))


GOOD_SKETCH = {
    "plan_path": {"exact_seconds": 0.25, "sketch_seconds": 0.10,
                  "speedup": 2.5, "exact_pull_floats": 1_048_576,
                  "sketch_pull_floats": 32_768},
    "scenarios": {
        "benign": {"batches": 4, "overflow_replans": 0,
                   "replan_rate": 0.0, "overflow_free": True},
        "adversarial": {"batches": 4, "overflow_replans": 4,
                        "replan_rate": 1.0, "overflow_free": True},
    },
    "bit_identical": True,
}


class TestSketchGate:
    def test_good_report_passes(self, tmp_path, capsys):
        check.gate_sketch(_write(tmp_path, GOOD_SKETCH))
        assert "2.50x" in capsys.readouterr().out

    @pytest.mark.parametrize("mutate", [
        lambda r: r.update(bit_identical=False),
        lambda r: r["plan_path"].update(speedup=1.1),
        lambda r: r["plan_path"].update(sketch_pull_floats=2_000_000),
        lambda r: r["scenarios"]["benign"].update(overflow_replans=1),
        lambda r: r["scenarios"]["adversarial"].update(overflow_replans=0),
        lambda r: r["scenarios"]["adversarial"].update(overflow_free=False),
    ])
    def test_each_broken_field_fails(self, tmp_path, mutate):
        r = copy.deepcopy(GOOD_SKETCH)
        mutate(r)
        with pytest.raises(check.GateFailure):
            check.gate_sketch(_write(tmp_path, r))


class TestDocsLinksGate:
    def test_clean_tree_passes(self, tmp_path):
        (tmp_path / "a.md").write_text("see [b](b.md)")
        (tmp_path / "b.md").write_text("ok")
        check.gate_docs_links(str(tmp_path))

    def test_broken_link_fails_with_path(self, tmp_path):
        (tmp_path / "a.md").write_text("see [gone](missing.md)")
        with pytest.raises(check.GateFailure) as ei:
            check.gate_docs_links(str(tmp_path))
        assert "missing.md" in str(ei.value)

    def test_external_and_anchor_links_skipped(self, tmp_path):
        (tmp_path / "a.md").write_text(
            "[x](https://example.com/y.md) [y](b.md#frag) [z](img.png)")
        (tmp_path / "b.md").write_text("ok")
        check.gate_docs_links(str(tmp_path))


class TestCli:
    def test_unknown_gate_rejected(self):
        with pytest.raises(SystemExit):
            check.main(["--gate", "nope"])

    def test_failure_exits_nonzero(self, tmp_path):
        r = dict(GOOD_REUSE, bit_identical=False)
        with pytest.raises(SystemExit) as ei:
            check.main(["--gate", "reuse", "--path", _write(tmp_path, r)])
        assert "[gate reuse]" in str(ei.value)

    def test_missing_report_is_a_clean_failure(self):
        with pytest.raises(SystemExit) as ei:
            check.main(["--gate", "elastic", "--path", "/nonexistent.json"])
        assert "missing report" in str(ei.value)

    def test_success_exits_zero(self, tmp_path, capsys):
        check.main(["--gate", "reuse", "--path", _write(tmp_path, GOOD_REUSE)])
        assert "[gate reuse] ok" in capsys.readouterr().out


class TestStaticAnalysisGate:
    def test_gate_registered_for_ci(self):
        assert "static-analysis" in check.GATES

    def test_passes_on_the_real_repo(self, capsys):
        # The full analyzer (all checkers + mutation self-tests) on the
        # shipped engine: the gate's clean path is the repo itself.
        check.gate_static_analysis()
        out = capsys.readouterr().out
        assert "overlap" in out and "self-test" in out

    def test_nonzero_bitmask_fails_naming_the_layers(self, monkeypatch):
        import repro.analysis.__main__ as analysis_main

        monkeypatch.setattr(analysis_main, "run",
                            lambda check="all", self_test=False: 2 | 16)
        with pytest.raises(check.GateFailure) as ei:
            check.gate_static_analysis()
        msg = str(ei.value)
        assert "[gate static-analysis]" in msg
        assert "18" in msg                       # the failing bitmask
        assert "determinism 2" in msg            # ...and its legend
        assert "self-test 16" in msg

    def test_cli_runs_the_gate(self, capsys):
        check.main(["--gate", "static-analysis"])
        assert "[gate static-analysis] ok" in capsys.readouterr().out
