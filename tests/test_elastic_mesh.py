"""Elastic-mesh tests: dead slots, resizes, and checkpointed wave replay.

Covers the ISSUE 6 acceptance criteria:

* snapshot re-projection — ``rebin_hist`` conserves per-cluster mass and
  a full 8→6→8 ``CachedSchedule.reproject`` round-trip replans from warm
  statistics with per-cluster ``K`` preserved;
* dead-slot assigner property — no strategy ever assigns load to an
  exact-0.0 slot, cross-checked against the brute-force optimum over the
  survivors, and the all-alive paths stay bit-identical to before;
* wave-granularity checkpointing — a slot killed mid-batch replays only
  the unfinished waves onto the survivors with bit-identical outputs;
* estimator mask-out — a dead slot's speed stays pinned at 0.0 no matter
  what observations arrive afterwards;
* cache regression — a died/rejoined slot forces a replan with reason
  ``"slot_dead"``, never an ``inf`` "speed drift".
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import scheduler as S
from repro.core import schedule_cache as SC
from repro.core.mapreduce import MapReduceConfig, MapReduceJob
from repro.core.slot_speeds import SlotSpeedEstimator, speed_drift

RNG = np.random.default_rng(7)

STRATEGIES = {
    "lpt": S.schedule_lpt,
    "multifit": S.schedule_multifit,
    "bss": S.schedule_bss,
}


# ---------------------------------------------------------------------------
# re-projection
# ---------------------------------------------------------------------------

class TestRebinHist:
    def test_mass_conserved(self):
        h = RNG.integers(0, 50, size=(8, 17)).astype(np.float64)
        for new_m in (1, 3, 6, 8, 11):
            out = SC.rebin_hist(h, new_m)
            assert out.shape == (new_m, 17)
            np.testing.assert_allclose(out.sum(axis=0), h.sum(axis=0),
                                       rtol=0, atol=1e-9)
            assert (out >= -1e-12).all()

    def test_same_m_is_copy(self):
        h = RNG.random((4, 5))
        out = SC.rebin_hist(h, 4)
        np.testing.assert_array_equal(out, h)
        assert out is not h

    def test_validation(self):
        with pytest.raises(ValueError):
            SC.rebin_hist(np.ones(5), 2)
        with pytest.raises(ValueError):
            SC.rebin_hist(np.ones((2, 5)), 0)

    def test_round_trip_preserves_column_sums(self):
        h = RNG.integers(0, 100, size=(8, 23)).astype(np.float64)
        back = SC.rebin_hist(SC.rebin_hist(h, 6), 8)
        np.testing.assert_allclose(back.sum(axis=0), h.sum(axis=0),
                                   rtol=0, atol=1e-9)


class TestSnapshotReproject:
    """Full warm-resize round-trip through a live job's cache."""

    def _batch(self, m, K=512, n=24, seed=0):
        rng = np.random.default_rng(seed)
        keys = (rng.zipf(1.3, size=(m, K)) % (n * 7 + 1)).astype(np.int32)
        vals = np.ones((m, K, 4), np.float32)
        return (jnp.asarray(keys), jnp.asarray(vals),
                jnp.ones((m, K), bool))

    def test_8_to_6_to_8(self):
        policy = SC.ReusePolicy(max_drift=0.5, revalidate_every=1)
        job = MapReduceJob(
            lambda s: s,
            MapReduceConfig(num_slots=8, num_clusters=24, scheduler="bss",
                            reuse=policy),
            backend="vmap")
        job.run(self._batch(8))
        snap8 = job.schedule_cache.snapshot
        key_dist8 = snap8.key_dist.copy()

        job.resize(6)
        snap6 = job.schedule_cache.snapshot
        assert snap6.schedule.num_slots == 6
        assert snap6.local_hist.shape[0] == 6
        # per-cluster mass (the global K the plan is built from) survives
        np.testing.assert_allclose(snap6.key_dist, key_dist8, atol=1e-6)
        assert job.schedule_cache.reprojections == 1
        r6 = job.run(self._batch(6))
        assert r6.plan_reason != "cold"

        job.resize(8)
        snap8b = job.schedule_cache.snapshot
        assert snap8b.schedule.num_slots == 8
        np.testing.assert_allclose(snap8b.key_dist, key_dist8, atol=1e-6)
        assert job.schedule_cache.reprojections == 2
        r8 = job.run(self._batch(8))
        assert r8.plan_reason != "cold"

    def test_k_per_shard_rescaled(self):
        sched = S.schedule_lpt(np.ones(10), 8)
        hist = np.tile(np.ones(10) / 8.0, (8, 1)) * 8
        import repro.core.pipeline as pipe
        waves = pipe.plan_waves(hist.sum(axis=0), sched.assignment,
                                sched.num_slots, num_chunks=1)
        snap = SC.CachedSchedule(
            schedule=sched, strategy="lpt", strategy_costs=None,
            waves=waves, capacity=4, chunk_caps=(4,),
            local_hist=hist, key_dist=hist.sum(axis=0), k_per_shard=12)
        seen = {}

        def planner(local_hist, key_dist, k_per_shard, prev):
            seen["k"] = k_per_shard
            seen["m"] = local_hist.shape[0]
            s2 = S.schedule_lpt(key_dist, local_hist.shape[0])
            return SC.CachedSchedule(
                schedule=s2, strategy="lpt", strategy_costs=None,
                waves=pipe.plan_waves(key_dist, s2.assignment, s2.num_slots, num_chunks=1),
                capacity=4, chunk_caps=(4,),
                local_hist=local_hist, key_dist=key_dist)

        out = snap.reproject(6, planner)
        # ceil(12 * 8 / 6) = 16: total plan-time pairs conserved
        assert seen == {"k": 16, "m": 6}
        assert out.k_per_shard == 16


# ---------------------------------------------------------------------------
# dead-slot assigner property
# ---------------------------------------------------------------------------

class TestDeadSlotAssignment:
    @pytest.mark.parametrize("name", sorted(STRATEGIES))
    def test_no_work_on_dead_slots(self, name):
        fn = STRATEGIES[name]
        for seed in range(5):
            rng = np.random.default_rng(seed)
            loads = rng.integers(1, 40, size=12).astype(float)
            speeds = np.array([1.0, 0.0, 0.7, 1.3, 0.0, 1.0])
            sched = fn(loads, 6, speeds=speeds)
            assert sched.slot_loads[1] == 0.0
            assert sched.slot_loads[4] == 0.0
            # dead slots are costless, not infinitely late
            assert sched.slot_finish[1] == 0.0
            np.testing.assert_allclose(sched.slot_loads.sum(), loads.sum())

    def test_matches_brute_force_over_survivors(self):
        """Makespan with dead slots == brute optimum on the alive subset."""
        rng = np.random.default_rng(3)
        loads = rng.integers(1, 30, size=9).astype(float)
        speeds = np.array([1.0, 0.0, 0.5, 1.5])
        full = S.schedule_brute(loads, 4, speeds=speeds)
        alive = S.schedule_brute(loads, 3, speeds=np.array([1.0, 0.5, 1.5]))
        assert full.makespan == pytest.approx(alive.makespan)
        assert full.slot_loads[1] == 0.0

    def test_hash_avoids_dead_slots(self):
        speeds = np.array([1.0, 1.0, 0.0, 1.0])
        sched = S.schedule_hash(np.arange(1, 33, dtype=float), 4,
                                speeds=speeds)
        assert sched.slot_loads[2] == 0.0

    def test_all_alive_unchanged(self):
        """Alive-compaction is a no-op when nobody is dead."""
        loads = np.arange(1, 14, dtype=float)
        for name, fn in STRATEGIES.items():
            a = fn(loads, 4).assignment
            b = fn(loads, 4, speeds=np.ones(4)).assignment
            np.testing.assert_array_equal(a, b)

    def test_speed_validation(self):
        with pytest.raises(ValueError):
            S.schedule_lpt(np.ones(4), 2, speeds=[1.0, -0.5])
        with pytest.raises(ValueError):
            S.schedule_lpt(np.ones(4), 2, speeds=[0.0, 0.0])


# ---------------------------------------------------------------------------
# estimator mask-out
# ---------------------------------------------------------------------------

class TestEstimatorMaskOut:
    def test_dead_slot_pinned_to_zero(self):
        est = SlotSpeedEstimator(num_slots=4, ewma=0.5)
        loads = np.full(4, 100.0)
        est.update(loads, np.array([1.0, 1.0, 2.0, 1.0]))
        est.set_slot_failure(2)
        assert est.speeds()[2] == 0.0
        # observations for a dead slot are discarded — it never
        # re-inherits work through a stale speed estimate
        est.update(loads, np.array([1.0, 1.0, 0.5, 1.0]))
        s = est.speeds()
        assert s[2] == 0.0
        assert (s[[0, 1, 3]] > 0).all()

    def test_rejoin(self):
        est = SlotSpeedEstimator(num_slots=3, ewma=0.5)
        est.update(np.full(3, 60.0), np.ones(3))
        est.set_slot_failure(1)
        assert est.speeds()[1] == 0.0
        est.set_slot_failure(1, dead=False)
        est.update(np.full(3, 60.0), np.ones(3))
        assert est.speeds()[1] > 0.0

    def test_speed_drift_dead_mismatch_is_inf(self):
        assert speed_drift(np.array([1.0, 1.0]),
                           np.array([1.0, 0.0])) == np.inf

    def test_resize_preserves_mask_semantics(self):
        est = SlotSpeedEstimator(num_slots=4, ewma=0.5)
        est.set_slot_failure(3)
        est.resize(2)
        assert est.dead_mask.shape == (2,)
        est.resize(5)
        assert est.dead_mask.shape == (5,)
        assert not est.dead_mask.any()


# ---------------------------------------------------------------------------
# cache: slot death forces a structural replan, not "speed drift"
# ---------------------------------------------------------------------------

class TestSlotDeadReplanReason:
    def _snapshot(self, speeds):
        import repro.core.pipeline as pipe
        key_dist = np.ones(8) * 10
        sched = S.Schedule.from_assignment(
            np.arange(8, dtype=np.int32) % 4, key_dist, 4, speeds=speeds)
        hist = np.tile(key_dist / 4.0, (4, 1))
        return SC.CachedSchedule(
            schedule=sched, strategy="lpt", strategy_costs=None,
            waves=pipe.plan_waves(key_dist, sched.assignment, sched.num_slots, num_chunks=1),
            capacity=8, chunk_caps=(8,),
            local_hist=hist, key_dist=key_dist)

    def test_death_reason_is_slot_dead(self):
        cache = SC.ScheduleCache(SC.ReusePolicy(max_drift=0.5,
                                                revalidate_every=1))
        cache.store(self._snapshot(speeds=np.ones(4)))
        d = cache.decide(cache.snapshot.local_hist,
                         fresh_speeds=np.array([1.0, 1.0, 0.0, 1.0]))
        assert d.action == "replan"
        assert d.reason == "slot_dead"
        assert cache.dead_replans == 1

    def test_rejoin_reason_is_slot_dead(self):
        cache = SC.ScheduleCache(SC.ReusePolicy(max_drift=0.5,
                                                revalidate_every=1))
        cache.store(self._snapshot(speeds=np.array([1.0, 1.0, 0.0, 1.0])))
        d = cache.decide(cache.snapshot.local_hist,
                         fresh_speeds=np.ones(4))
        assert d.reason == "slot_dead"

    def test_same_dead_set_reuses(self):
        cache = SC.ScheduleCache(SC.ReusePolicy(max_drift=0.5,
                                                revalidate_every=1))
        speeds = np.array([1.0, 1.0, 0.0, 1.0])
        cache.store(self._snapshot(speeds=speeds))
        d = cache.decide(cache.snapshot.local_hist, fresh_speeds=speeds)
        assert d.action == "reuse"
        assert cache.dead_replans == 0


# ---------------------------------------------------------------------------
# wave-checkpointed replay
# ---------------------------------------------------------------------------

class TestWaveCheckpointReplay:
    def _make(self, checkpoint=True, chunks=4):
        return MapReduceJob(
            lambda s: s,
            MapReduceConfig(num_slots=8, num_clusters=48, scheduler="bss",
                            pipeline_chunks=chunks,
                            checkpoint_waves=checkpoint),
            backend="vmap")

    def _batch(self, seed=0, K=1024):
        rng = np.random.default_rng(seed)
        keys = (rng.zipf(1.25, size=(8, K)) % 337).astype(np.int32)
        vals = np.ones((8, K, 8), np.float32)
        return (jnp.asarray(keys), jnp.asarray(vals),
                jnp.ones((8, K), bool))

    def test_uninterrupted_checkpointed_is_bit_identical(self):
        batch = self._batch()
        base = self._make(checkpoint=False).run(batch)
        ck = self._make(checkpoint=True).run(batch)
        np.testing.assert_array_equal(base.values, ck.values)
        np.testing.assert_array_equal(base.counts, ck.counts)

    def test_mid_wave_kill_replays_remainder_bit_identically(self):
        batch = self._batch()
        base = self._make(checkpoint=False).run(batch)
        job = self._make(checkpoint=True)
        job.set_slot_failure(3, at_wave=2)
        res = job.run(batch)
        np.testing.assert_array_equal(base.values, res.values)
        np.testing.assert_array_equal(base.counts, res.counts)
        n_waves = job.last_checkpoint.num_chunks
        assert job.last_checkpoint_wave == 2
        assert job.last_replayed_waves <= n_waves - job.last_checkpoint_wave
        # the recovery plan routes nothing to the corpse
        assert job.last_replay_plan.schedule.slot_loads[3] == 0.0
        assert bool(job.dead_slots[3])
        ev = [e["event"] for e in job.mesh_events]
        assert "slot_dead" in ev

    def test_kill_at_wave_zero(self):
        batch = self._batch(seed=2)
        base = self._make(checkpoint=False).run(batch)
        job = self._make(checkpoint=True)
        job.set_slot_failure(0, at_wave=0)
        res = job.run(batch)
        np.testing.assert_array_equal(base.values, res.values)
        assert job.last_replay_plan.schedule.slot_loads[0] == 0.0

    def test_kill_requires_checkpointing(self):
        job = self._make(checkpoint=False)
        with pytest.raises(ValueError):
            job.set_slot_failure(1, at_wave=1)

    def test_checkpoint_waves_excludes_measured_timings(self):
        with pytest.raises(ValueError):
            MapReduceJob(
                lambda s: s,
                MapReduceConfig(num_slots=8, num_clusters=16,
                                checkpoint_waves=True,
                                measure_timings=True),
                backend="vmap")

    def test_next_batch_plans_around_the_corpse(self):
        job = self._make(checkpoint=True)
        job.set_slot_failure(5, at_wave=1)
        job.run(self._batch())
        res2 = job.run(self._batch(seed=1))
        assert res2.schedule.slot_loads[5] == 0.0
        assert job.current_speeds()[5] == 0.0
