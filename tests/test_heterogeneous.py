"""Q||C_max core tests: speed-aware strategies, estimator, feedback loop.

Covers the ISSUE 3 acceptance criteria:

* property-style sweep — every speed-aware strategy's makespan ≤ the hash
  baseline, on seeds × speed configurations;
* regression pin — with ``speeds=None`` / all-ones every strategy
  reproduces the pre-refactor assignments **exactly** (golden JSON
  captured before the refactor, ``tests/data/golden_assignments.json``);
* bit-identity — job outputs are unchanged under any injected slowdown
  (speeds only move *where* clusters go, never what they compute).
"""

import json
import pathlib

import numpy as np
import pytest

from repro.core import scheduler as S
from repro.core import simulator as sim
from repro.core import pipeline as pipe
from repro.core.slot_speeds import SlotSpeedEstimator, speed_drift

GOLDEN = pathlib.Path(__file__).parent / "data" / "golden_assignments.json"

SPEED_CONFIGS = [
    None,                                   # P||C_max
    "uniform",                              # explicit all-ones
    "one_straggler",                        # one slot at 0.5x
    "two_tiers",                            # half the fleet at 0.75x
    "mixed",                                # arbitrary heterogeneous mix
]


def _speeds(kind, m, rng):
    if kind is None:
        return None
    if kind == "uniform":
        return np.ones(m)
    sp = np.ones(m)
    if kind == "one_straggler":
        sp[m // 2] = 0.5
    elif kind == "two_tiers":
        sp[: m // 2] = 0.75
    elif kind == "mixed":
        sp = rng.uniform(0.3, 1.5, size=m)
    return sp


def _loads(seed, n=200):
    rng = np.random.default_rng(seed)
    return rng.zipf(1.3, n).clip(1, 20_000).astype(float), rng


# ---------------------------------------------------------------------------
# (a) property sweep: speed-aware strategies beat the oblivious baseline.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("kind", SPEED_CONFIGS)
def test_speed_aware_beats_hash(seed, kind):
    m = 12
    loads, rng = _loads(seed)
    speeds = _speeds(kind, m, rng)
    hash_s = S.schedule_hash(loads, m, keys=np.arange(loads.size),
                             speeds=speeds)
    for name in ("lpt", "multifit", "bss"):
        sched = S.get_scheduler(name)(loads, m, speeds=speeds)
        assert sched.makespan <= hash_s.makespan + 1e-9, (name, kind)
        # structural invariants under any speed vector
        assert ((sched.assignment >= 0) & (sched.assignment < m)).all()
        assert np.isclose(sched.slot_loads.sum(), loads.sum())
        # makespan can never beat the aggregate-speed lower bound
        assert sched.makespan >= sched.ideal_finish - 1e-9


@pytest.mark.parametrize("seed", range(4))
def test_speed_aware_near_oracle_on_tiny(seed):
    """EFT strategies stay close to the exact Q||C_max optimum (brute)."""
    rng = np.random.default_rng(seed)
    loads = rng.integers(1, 50, size=10).astype(float)
    m = 3
    speeds = np.asarray([1.0, 0.5, 1.5])
    opt = S.schedule_brute(loads, m, speeds=speeds)
    for name in ("lpt", "multifit", "bss"):
        sched = S.get_scheduler(name)(loads, m, speeds=speeds)
        assert sched.makespan >= opt.makespan - 1e-9
        assert sched.makespan <= 2.0 * opt.makespan + 1e-9  # Q-LPT bound


def test_straggler_cut_at_least_25pct():
    """The acceptance bench in miniature: one 2x-slow slot, zipf keys."""
    loads, _ = _loads(0, n=480)
    m = 8
    speeds = np.ones(m)
    speeds[3] = 0.5
    for name in ("lpt", "multifit", "bss"):
        fn = S.get_scheduler(name)
        oblivious = fn(loads, m)
        aware = fn(loads, m, speeds=speeds)
        t_obl = sim.estimate_reduce_time(loads, oblivious, speeds=speeds)
        t_aware = sim.estimate_reduce_time(loads, aware, speeds=speeds)
        assert t_aware <= 0.75 * t_obl, (name, t_aware, t_obl)


# ---------------------------------------------------------------------------
# (b) regression pin: uniform speeds reproduce pre-refactor assignments.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("speeds_kind", [None, "uniform"])
def test_golden_assignments_unchanged(speeds_kind):
    golden = json.loads(GOLDEN.read_text())
    for key, case in golden.items():
        if case.get("proc"):   # R||C_max fixtures: checked in test_multi_job
            continue
        rng = np.random.default_rng(case["seed"])
        loads = rng.zipf(1.3, case["n"]).clip(1, 20_000).astype(float)
        m = case["m"]
        speeds = None if speeds_kind is None else np.ones(m)
        for name, want in case["assignments"].items():
            if name == "brute":
                mb = min(m, 4)
                got = S.schedule_brute(
                    loads[:12], mb,
                    speeds=None if speeds is None else np.ones(mb),
                ).assignment
            elif name == "lpt_jax":
                got, _ = S.lpt_assign_jax(loads, m, speeds=speeds)
                got = np.asarray(got)
            elif name == "hash":
                got = S.schedule_hash(loads, m, keys=np.arange(case["n"]),
                                      speeds=speeds).assignment
            else:
                got = S.get_scheduler(name)(loads, m, speeds=speeds).assignment
            assert np.array_equal(got, np.asarray(want)), (key, name)


def test_uniform_speeds_metrics_coincide():
    """With nominal speeds the Q metrics equal the P metrics exactly."""
    loads, _ = _loads(1)
    sched = S.schedule_bss(loads, 10, speeds=np.ones(10))
    assert sched.makespan == sched.max_load
    assert sched.finish_ratio == sched.balance_ratio
    assert sched.ideal_finish == sched.ideal_load


# ---------------------------------------------------------------------------
# Schedule construction (the direct-construction satellite).
# ---------------------------------------------------------------------------


def test_schedule_direct_construction_derives_metrics():
    sched = S.Schedule(np.asarray([0, 1, 1, 2], np.int32), 3)
    assert sched.slot_loads is not None
    assert np.array_equal(sched.slot_loads, [1.0, 2.0, 1.0])
    assert sched.max_load == 2.0
    assert sched.makespan == 2.0
    assert np.array_equal(sched.slot_speeds, np.ones(3))
    assert sched.balance_ratio == pytest.approx(1.5)


def test_schedule_speed_validation():
    # Exact 0.0 is the elastic-mesh dead-slot convention — legal, and the
    # dead slot's finish time is 0 when it holds no load.
    sched = S.Schedule(np.zeros(2, np.int32), 2,
                       slot_speeds=np.asarray([1.0, 0.0]))
    assert sched.slot_finish[1] == 0.0
    with pytest.raises(ValueError):
        S.Schedule(np.zeros(2, np.int32), 2, slot_speeds=np.ones(3))
    with pytest.raises(ValueError):
        S.normalize_speeds([1.0, -1.0], 2)
    with pytest.raises(ValueError):            # all dead: nothing can run
        S.normalize_speeds([0.0, 0.0], 2)
    with pytest.raises(ValueError):
        S.normalize_speeds([1.0, float("nan")], 2)


def test_schedule_finish_metrics():
    loads = np.asarray([4.0, 4.0])
    sched = S.Schedule.from_assignment(
        np.asarray([0, 1]), loads, 2, speeds=[1.0, 0.5])
    assert sched.makespan == pytest.approx(8.0)       # slow slot: 4 / 0.5
    assert sched.ideal_finish == pytest.approx(8.0 / 1.5)
    assert np.allclose(sched.slot_finish, [4.0, 8.0])


# ---------------------------------------------------------------------------
# Slot-speed estimator + drift trigger.
# ---------------------------------------------------------------------------


class TestSlotSpeedEstimator:
    def test_no_observation_is_none(self):
        est = SlotSpeedEstimator(4)
        assert est.speeds() is None
        assert np.array_equal(est.speeds(default_ones=True), np.ones(4))

    def test_recovers_relative_speeds(self):
        est = SlotSpeedEstimator(4, ewma=1.0)
        work = np.asarray([100.0, 100.0, 100.0, 100.0])
        secs = work / np.asarray([1.0, 0.5, 1.0, 1.0])  # slot 1 at half rate
        sp = est.update(work, secs)
        assert sp[1] == pytest.approx(sp[0] * 0.5)
        assert np.isclose(sp.mean(), 1.0)

    def test_ewma_converges_on_step_change(self):
        est = SlotSpeedEstimator(2, ewma=0.5)
        for _ in range(3):
            est.update([10.0, 10.0], [10.0, 10.0])    # both nominal
        for _ in range(8):
            est.update([10.0, 10.0], [10.0, 40.0])    # slot 1 drops to 0.25x
        sp = est.speeds()
        assert sp[1] / sp[0] == pytest.approx(0.25, rel=0.05)

    def test_idle_slot_keeps_prior(self):
        est = SlotSpeedEstimator(2, ewma=1.0)
        est.update([10.0, 10.0], [10.0, 20.0])
        before = est.speeds().copy()
        est.update([10.0, 0.0], [10.0, 0.0])          # slot 1 idle
        after = est.speeds()
        assert after[1] / after[0] == pytest.approx(before[1] / before[0])

    def test_partially_observed_fleet_is_mean_one_over_full_vector(self):
        """Unobserved slots fill in at the observed mean, and the returned
        mixed vector is normalised over ALL slots (pinned semantics) —
        earliest-finish assignment is not biased toward unobserved slots."""
        est = SlotSpeedEstimator(4, ewma=1.0)
        # only slots 0 and 1 observed: rates 200 and 100 work/s
        est.update([100.0, 100.0, 0.0, 0.0], [0.5, 1.0, 0.0, 0.0])
        sp = est.speeds()
        assert sp.mean() == pytest.approx(1.0)
        # relative ratio among observed slots preserved
        assert sp[0] / sp[1] == pytest.approx(2.0)
        # unobserved slots sit exactly at the (normalised) observed mean
        assert sp[2] == pytest.approx(1.0) and sp[3] == pytest.approx(1.0)

    def test_lone_observed_straggler_reads_nominal(self):
        """With ONE observed slot there is no relative information: the
        estimator reports nominal for everyone (documented limitation of
        relative-only estimation, not a straggler signal)."""
        est = SlotSpeedEstimator(3, ewma=1.0)
        est.update([100.0, 0.0, 0.0], [50.0, 0.0, 0.0])
        assert np.allclose(est.speeds(), 1.0)

    def test_floor_clamps_pathological_sample(self):
        est = SlotSpeedEstimator(2, ewma=1.0, floor=0.05)
        est.update([10.0, 10.0], [1e-9, 10.0])        # absurd rate on slot 0
        sp = est.speeds()
        assert sp.max() <= 1 / 0.05 + 1e-9
        assert sp.min() >= 0.05 - 1e-9

    def test_json_round_trip(self):
        est = SlotSpeedEstimator(3, ewma=0.3, floor=0.1)
        est.update([5.0, 5.0, 0.0], [5.0, 10.0, 0.0])
        clone = SlotSpeedEstimator.from_json(est.to_json())
        assert np.allclose(clone.speeds(), est.speeds())
        assert clone.observations == est.observations

    def test_validation(self):
        with pytest.raises(ValueError):
            SlotSpeedEstimator(2, ewma=0.0)
        with pytest.raises(ValueError):
            SlotSpeedEstimator(2, floor=1.5)
        with pytest.raises(ValueError):
            SlotSpeedEstimator(2).update([1.0], [1.0])


class TestSpeedDrift:
    def test_none_and_uniform(self):
        assert speed_drift(None, None) == 0.0
        assert speed_drift(np.ones(3), None) == 0.0
        assert speed_drift(None, np.ones(3)) == 0.0

    def test_one_sided_none_vs_nonnominal_is_conservative(self):
        """A measured, non-nominal side against 'no measurement' is inf —
        an estimator reset must not read as near-zero drift (it used to
        substitute all-ones and report ~0, so max_speed_drift never
        fired on a plan built from measured speeds)."""
        measured = np.asarray([1.0, 0.5, 1.2])
        assert speed_drift(measured, None) == float("inf")
        assert speed_drift(None, measured) == float("inf")

    def test_symmetric(self):
        ref = np.asarray([1.0, 1.0])
        slow = np.asarray([1.0, 0.5])
        assert speed_drift(ref, slow) == pytest.approx(1.0)
        assert speed_drift(slow, ref) == pytest.approx(1.0)

    def test_shape_mismatch(self):
        with pytest.raises(ValueError):
            speed_drift(np.ones(2), np.ones(3))


# ---------------------------------------------------------------------------
# Simulator + pipeline threading.
# ---------------------------------------------------------------------------


def test_estimate_reduce_time_scales_with_speed():
    loads, _ = _loads(2, n=60)
    sched = S.schedule_lpt(loads, 4)
    base = sim.estimate_reduce_time(loads, sched)
    slow = sim.estimate_reduce_time(
        loads, sched, speeds=np.asarray([1.0, 1.0, 1.0, 0.5]))
    assert slow > base           # a straggler can only hurt a fixed schedule
    uniform = sim.estimate_reduce_time(loads, sched, speeds=np.ones(4))
    assert uniform == base       # nominal speeds are exactly the P model


def test_pick_strategy_speed_aware():
    loads, _ = _loads(3, n=200)
    speeds = np.ones(8)
    speeds[0] = 0.5
    name_p, sched_p, _ = sim.pick_strategy(loads, 8)
    name_q, sched_q, costs = sim.pick_strategy(loads, 8, speeds=speeds)
    # The Q-aware winner's estimated makespan under the true speeds must
    # be at least as good as pricing the P winner under those speeds.
    t_p = sim.estimate_reduce_time(loads, sched_p, speeds=speeds)
    t_q = sim.estimate_reduce_time(loads, sched_q, speeds=speeds)
    assert t_q <= t_p + 1e-9
    assert set(costs) == set(S.AUTO_CANDIDATES)


def test_estimate_replan_benefit_sees_straggler():
    """A schedule that piled work on a now-slow slot shows a big benefit."""
    loads, _ = _loads(4, n=200)
    m = 4
    stale = S.schedule_bss(loads, m)     # balanced for uniform slots
    speeds = np.ones(m)
    speeds[int(np.argmax(stale.slot_loads))] = 0.4
    verdict = sim.estimate_replan_benefit(loads, stale, speeds=speeds)
    assert verdict["benefit"] > 0.0


def test_plan_waves_uniform_speeds_identical():
    loads, _ = _loads(5, n=120)
    sched = S.schedule_bss(loads, 6)
    base = pipe.plan_waves(loads, sched.assignment, 6, 4)
    ones = pipe.plan_waves(loads, sched.assignment, 6, 4, speeds=np.ones(6))
    assert np.array_equal(base.rank_of_cluster, ones.rank_of_cluster)
    assert np.array_equal(base.chunk_of_cluster, ones.chunk_of_cluster)


def test_plan_waves_speed_ordering():
    """Clusters on a slow slot rank later (longer finish) than equal loads
    on a fast slot, and the wave-plan invariants hold."""
    loads = np.asarray([10.0, 10.0, 5.0, 5.0])
    assignment = np.asarray([0, 1, 0, 1])
    speeds = np.asarray([1.0, 0.25])
    plan = pipe.plan_waves(loads, assignment, 2, 2, speeds=speeds)
    # finish costs: [10, 40, 5, 20] -> rank order 2, 0, 3, 1
    assert np.array_equal(plan.rank_of_cluster, [1, 3, 0, 2])
    # invariants: dense chunk ids, every cluster in exactly one chunk
    assert plan.chunk_of_cluster.min() == 0
    assert plan.chunk_of_cluster.max() == plan.num_chunks - 1


# ---------------------------------------------------------------------------
# Job-level: feedback loop, bit-identity, snapshot round-trip, warm start.
# ---------------------------------------------------------------------------


def _job_batch(slots, K, seed, alpha=1.25, n=64):
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    keys = (rng.zipf(alpha, size=(slots, K)) % 2003).astype(np.int32)
    vals = np.ones((slots, K, 4), np.float32)
    valid = np.ones((slots, K), bool)
    return (jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(valid))


class TestJobSpeedLoop:
    slots, K, n = 4, 2048, 48

    def _mk(self, **kw):
        from repro.core.mapreduce import MapReduceConfig, MapReduceJob

        cfg = MapReduceConfig(num_slots=self.slots, num_clusters=self.n,
                              scheduler="bss", **kw)
        return MapReduceJob(lambda s: s, cfg, backend="vmap")

    def test_outputs_bit_identical_under_any_slowdown(self):
        # factors are wall-clock multipliers (>1 slow, <1 fast) — outputs
        # must be bit-identical in every direction
        base = self._mk()
        for factor in (0.5, 0.1, 2.0):
            slowed = self._mk(estimate_speeds=True)
            slowed.set_slot_slowdown(2, factor)
            for i in range(3):
                b = _job_batch(self.slots, self.K, i)
                rb, rs = base.run(b), slowed.run(b)
                assert np.array_equal(rb.values, rs.values), factor
                assert np.array_equal(rb.counts, rs.counts), factor

    def test_static_speeds_bit_identical_and_compensating(self):
        base = self._mk()
        speeds = (1.0, 1.0, 0.5, 1.0)
        job = self._mk(speeds=speeds)
        b = _job_batch(self.slots, self.K, 0)
        rb, rj = base.run(b), job.run(b)
        assert np.array_equal(rb.values, rj.values)
        assert np.array_equal(rb.counts, rj.counts)
        # the slow slot is handed less load than a fair share
        assert rj.schedule.slot_loads[2] < rj.schedule.ideal_load
        assert rj.schedule.finish_ratio <= rb.schedule.finish_ratio + 1e-9

    def test_speed_drift_triggers_replan(self):
        from repro.core.schedule_cache import ReusePolicy

        # ewma=1.0: the estimate converges in one observation, so exactly
        # one speed replan fires and reuse resumes immediately after.
        job = self._mk(estimate_speeds=True, speed_ewma=1.0,
                       reuse=ReusePolicy(max_drift=0.9, max_speed_drift=0.25))
        reasons = []
        for i in range(5):
            if i == 2:
                job.set_slot_slowdown(1, 2.0)   # slot 1 -> 2x wall-clock
            reasons.append(job.run(_job_batch(self.slots, self.K, i)).plan_reason)
        assert reasons[0] == "cold"
        assert "speed_drift" in reasons[2:]
        assert job.schedule_cache.speed_replans >= 1
        # after the replan the estimate is stable again -> reuse resumes
        assert reasons[-1] in ("ok", "unchecked")

    def test_snapshot_roundtrip_includes_speeds(self):
        from repro.core.schedule_cache import CachedSchedule, ReusePolicy

        job = self._mk(speeds=(1.0, 0.5, 1.0, 1.0),
                       reuse=ReusePolicy(max_drift=0.5))
        job.run(_job_batch(self.slots, self.K, 0))
        snap = job.schedule_cache.snapshot
        clone = CachedSchedule.from_json(
            json.loads(json.dumps(snap.to_json())))
        assert np.allclose(clone.slot_speeds, snap.slot_speeds)
        assert np.array_equal(clone.schedule.assignment,
                              snap.schedule.assignment)
        assert clone.capacity == snap.capacity
        assert clone.chunk_caps == snap.chunk_caps

    def test_warm_start_skips_cold_plan(self):
        from repro.core.schedule_cache import CachedSchedule, ReusePolicy

        donor = self._mk(reuse=ReusePolicy(max_drift=0.5))
        donor.run(_job_batch(self.slots, self.K, 0))
        blob = json.dumps(donor.schedule_cache.snapshot.to_json())

        warm = self._mk(reuse=ReusePolicy(max_drift=0.5))
        warm.load_snapshot(json.loads(blob))
        res = warm.run(_job_batch(self.slots, self.K, 1))
        assert res.plan_reason != "cold"
        assert res.reused
        # and the replayed outputs match a cold job on the same batch
        cold = self._mk()
        ref = cold.run(_job_batch(self.slots, self.K, 1))
        assert np.array_equal(res.values, ref.values)
        assert np.array_equal(res.counts, ref.counts)

    def test_warm_start_with_measured_speeds_still_reuses(self):
        """A snapshot built from MEASURED (non-nominal) speeds must warm
        start too: load_snapshot seeds the estimator with the plan-time
        speeds, so the first drift check is not the conservative
        inf-vs-None replan."""
        import json as _json

        from repro.core.schedule_cache import ReusePolicy

        donor = self._mk(estimate_speeds=True, speed_ewma=1.0,
                         reuse=ReusePolicy(max_drift=0.9,
                                           max_speed_drift=0.25))
        donor.set_slot_slowdown(1, 2.0)
        for i in range(3):
            donor.run(_job_batch(self.slots, self.K, i))
        snap = donor.schedule_cache.snapshot
        assert not np.allclose(snap.slot_speeds, 1.0)

        warm = self._mk(estimate_speeds=True, speed_ewma=1.0,
                        reuse=ReusePolicy(max_drift=0.9,
                                          max_speed_drift=0.25))
        warm.load_snapshot(_json.loads(_json.dumps(snap.to_json())))
        assert np.allclose(warm.speed_estimator.speeds(),
                           snap.slot_speeds / np.mean(snap.slot_speeds))
        res = warm.run(_job_batch(self.slots, self.K, 3))
        assert res.reused and res.plan_reason == "ok"
        assert res.speed_drift < 0.25

    def test_load_snapshot_validates(self):
        from repro.core.schedule_cache import ReusePolicy

        donor = self._mk(reuse=ReusePolicy())
        donor.run(_job_batch(self.slots, self.K, 0))
        blob = donor.schedule_cache.snapshot.to_json()
        no_reuse = self._mk()
        with pytest.raises(ValueError):
            no_reuse.load_snapshot(blob)
        from repro.core.mapreduce import MapReduceConfig, MapReduceJob

        other = MapReduceJob(
            lambda s: s,
            MapReduceConfig(num_slots=self.slots, num_clusters=self.n + 8,
                            scheduler="bss",
                            reuse=ReusePolicy()),
            backend="vmap")
        with pytest.raises(ValueError):
            other.load_snapshot(blob)

    def test_slowdown_validation(self):
        job = self._mk()
        with pytest.raises(ValueError):
            job.set_slot_slowdown(99, 0.5)
        with pytest.raises(ValueError):
            job.set_slot_slowdown(0, -1.0)
        # Factor 0 is the elastic-mesh limit: the slot is dead, not slow.
        job.set_slot_slowdown(0, 0.0)
        assert bool(job.dead_slots[0])
        assert job.current_speeds()[0] == 0.0


def test_lpt_assign_jax_integer_loads_fractional_speeds():
    """Integer loads must not truncate fractional speeds (dtype promotion)."""
    import jax.numpy as jnp

    loads = jnp.asarray([5, 3, 2, 2], jnp.int32)
    assign, slot_loads = S.lpt_assign_jax(loads, 2, speeds=[1.0, 0.5])
    got = np.bincount(np.asarray(assign), weights=[5, 3, 2, 2], minlength=2)
    assert got.min() > 0          # the slow slot still gets work
    host = S.schedule_lpt(np.asarray([5.0, 3.0, 2.0, 2.0]), 2,
                          speeds=np.asarray([1.0, 0.5]))
    assert (got / np.asarray([1.0, 0.5])).max() == pytest.approx(host.makespan)


def test_external_timings_disable_synthetic_model():
    """A real measurement must not be diluted by synthetic nominal samples."""
    from repro.core.mapreduce import MapReduceConfig, MapReduceJob

    job = MapReduceJob(
        lambda s: s,
        MapReduceConfig(num_slots=4, num_clusters=48, scheduler="bss",
                        estimate_speeds=True, speed_ewma=0.4),
        backend="vmap")
    work = np.asarray([100.0, 100.0, 100.0, 100.0])
    job.observe_slot_times(work, work / np.asarray([1.0, 0.5, 1.0, 1.0]))
    for i in range(3):
        job.run(_job_batch(4, 1024, i))   # synthetic model must stay out
    sp = job.speed_estimator.speeds()
    assert sp[1] / sp[0] == pytest.approx(0.5)


def test_parse_slowdowns():
    from repro.launch.serve import parse_slowdowns

    assert parse_slowdowns(None) == []
    assert parse_slowdowns(["3:0.5", "1:2.0"]) == [(3, 0.5), (1, 2.0)]
    with pytest.raises(SystemExit):
        parse_slowdowns(["nope"])
    with pytest.raises(SystemExit):
        parse_slowdowns(["1:-2"])
    # factor 0 is the elastic-mesh fault injection: slot 1 is dead
    assert parse_slowdowns(["1:0"]) == [(1, 0.0)]


# ---------------------------------------------------------------------------
# Serving engine: lane speeds shape admission.
# ---------------------------------------------------------------------------


def _plan_only_engine(**ecfg_kw):
    """A REAL Engine (full ``__init__``) that is only ever planned with.

    Construction goes through ``Engine.__init__`` so the lane-speed
    normalization under test is the production one — params stay ``None``
    (``plan()``/``maybe_replan_waiting`` never touch the model, and the
    decode jit is lazy).
    """
    from repro.configs import get_smoke
    from repro.serve.engine import Engine, EngineConfig

    return Engine(get_smoke("smollm-360m"), None, EngineConfig(**ecfg_kw))


def test_engine_lane_speeds_shape_admission():
    """Slow lanes get proportionally less decode load (no model needed —
    plan() is pure scheduling)."""
    from repro.serve.engine import Engine, EngineConfig, Request

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(3, 100, 8).astype(np.int32),
                    max_new=int(rng.integers(8, 64))) for i in range(32)]
    lane_speeds = np.asarray([1.0, 1.0, 1.0, 0.25])
    eng = _plan_only_engine(lanes=4, scheduler="os4m", lane_speeds=lane_speeds)
    by_lane = Engine.plan(eng, reqs)
    loads = np.zeros(4)
    for lane, rs in by_lane.items():
        loads[lane] = sum(r.load for r in rs)
    # the 4x-slow lane holds well under a fair share
    assert loads[3] < loads.sum() / 4
    assert eng.last_finish_ratio < 2.0
    # oblivious plan for contrast: same requests, no speeds
    eng2 = _plan_only_engine(lanes=4, scheduler="os4m")
    Engine.plan(eng2, reqs)
    obl = S.schedule_bss(np.asarray([r.load for r in reqs]), 4)
    aware_makespan = (loads / lane_speeds).max()
    obl_makespan = (obl.slot_loads / lane_speeds).max()
    assert aware_makespan <= obl_makespan + 1e-9


def _some_requests(n=24, seed=0):
    from repro.serve.engine import Request

    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(3, 100, 8).astype(np.int32),
                    max_new=int(rng.integers(8, 64))) for i in range(n)]


def test_engine_configured_speeds_normalized_once():
    """Regression (ISSUE 4): Engine.__init__ used to validate the
    configured lane_speeds and DISCARD the result — lane_speeds() handed
    the schedulers the raw vector while metered speeds arrived mean-1.
    Now the stored, returned vector is mean-1, and a uniform [2, 2, 2, 2]
    plans identically to None."""
    from repro.serve.engine import Engine

    uniform2 = _plan_only_engine(lanes=4, scheduler="os4m",
                                 lane_speeds=[2.0, 2.0, 2.0, 2.0])
    assert np.allclose(uniform2.lane_speeds(), 1.0)   # normalised to mean 1
    baseline = _plan_only_engine(lanes=4, scheduler="os4m")
    assert baseline.lane_speeds() is None
    reqs_a, reqs_b = _some_requests(), _some_requests()
    plan_a = Engine.plan(uniform2, reqs_a)
    plan_b = Engine.plan(baseline, reqs_b)
    for lane in range(4):
        assert [r.rid for r in plan_a[lane]] == [r.rid for r in plan_b[lane]]
    # non-uniform vectors come back mean-1 with ratios preserved
    eng = _plan_only_engine(lanes=4, scheduler="os4m",
                            lane_speeds=[1.0, 1.0, 1.0, 0.25])
    sp = eng.lane_speeds()
    assert sp.mean() == pytest.approx(1.0)
    assert sp[0] / sp[3] == pytest.approx(4.0)


def test_engine_mid_run_replan_rebalances_waiting_queues():
    """When the measured lane speeds drift past the threshold, the engine
    re-plans the WAITING queues globally (never migrating running work)."""
    from repro.serve.engine import Engine

    eng = _plan_only_engine(lanes=4, scheduler="os4m", adaptive=True,
                            replan_on_drift=True, max_speed_drift=0.25)
    reqs = _some_requests(n=32)
    queues = Engine.plan(eng, reqs)
    # planned with no measurements -> nominal baseline
    assert np.allclose(eng._planned_speeds, 1.0)
    # lanes decode: lane 2 measures 4x slower than the rest
    eng.lane_meter.update([40.0, 40.0, 10.0, 40.0], [1.0, 1.0, 1.0, 1.0])
    assert Engine.maybe_replan_waiting(eng, queues)
    assert eng.replans == 1
    assert eng.last_replan_drift > 0.25
    loads = np.asarray([sum(r.load for r in queues[ln]) for ln in range(4)])
    # the measured-slow lane now holds under a fair share of the queue
    assert loads[2] < loads.sum() / 4
    # requests were re-homed consistently (lane field matches its queue)
    for lane in range(4):
        assert all(r.lane == lane for r in queues[lane])
    # stable speeds -> no further replan
    eng.lane_meter.update([40.0, 40.0, 10.0, 40.0], [1.0, 1.0, 1.0, 1.0])
    assert not Engine.maybe_replan_waiting(eng, queues)
    assert eng.replans == 1


def test_engine_replan_skips_when_nothing_waiting():
    from repro.serve.engine import Engine

    eng = _plan_only_engine(lanes=2, scheduler="os4m", adaptive=True,
                            replan_on_drift=True, max_speed_drift=0.1)
    queues = {0: [], 1: []}
    eng._planned_speeds = np.ones(2)
    eng.lane_meter.update([10.0, 40.0], [1.0, 1.0])
    assert not Engine.maybe_replan_waiting(eng, queues)
    assert eng.replans == 0
