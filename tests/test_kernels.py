"""Per-kernel shape/dtype sweeps against the pure-jnp oracles."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import decode_attention, flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.histogram.ops import histogram
from repro.kernels.histogram.ref import histogram_ref
from repro.kernels.moe_dispatch.ops import dispatch_ranks, dispatch_to_buckets
from repro.kernels.moe_dispatch.ref import (dispatch_ranks_ref,
                                            dispatch_to_buckets_ref)
from repro.kernels.segment_reduce.ops import segment_reduce_sorted
from repro.kernels.segment_reduce.ref import segment_reduce_sorted_ref


@pytest.mark.parametrize("n,bins", [(1, 1), (100, 7), (2048, 1024),
                                    (5000, 2500), (4096, 4096), (777, 13)])
def test_histogram_sweep(rng, n, bins):
    ids = jnp.asarray(rng.integers(-1, bins + 2, n), jnp.int32)  # incl. oob
    w = jnp.asarray(rng.random(n), jnp.float32)
    np.testing.assert_allclose(histogram(ids, w, bins),
                               histogram_ref(ids, w, bins), atol=1e-4)


@pytest.mark.parametrize("n,s,v", [(7, 3, 2), (300, 17, 4), (2048, 600, 8),
                                   (1000, 1000, 128), (1536, 2048, 16)])
def test_segment_reduce_sweep(rng, n, s, v):
    seg = np.sort(rng.integers(0, s, n)).astype(np.int32)
    vals = rng.standard_normal((n, v)).astype(np.float32)
    np.testing.assert_allclose(
        segment_reduce_sorted(jnp.asarray(vals), jnp.asarray(seg), s),
        segment_reduce_sorted_ref(jnp.asarray(vals), jnp.asarray(seg), s),
        atol=1e-4)


@pytest.mark.parametrize(
    "b,hq,hkv,t,s,d,causal,bq,bk",
    [(1, 2, 2, 128, 128, 64, True, 64, 64),
     (2, 4, 2, 100, 100, 32, True, 64, 64),     # GQA, ragged seq
     (1, 8, 1, 256, 256, 64, False, 128, 128),  # MQA, non-causal
     (2, 2, 2, 64, 192, 32, True, 32, 64),      # suffix-aligned causal
     (1, 4, 4, 33, 177, 16, True, 32, 64)])
def test_flash_attention_sweep(rng, b, hq, hkv, t, s, d, causal, bq, bk):
    q = jnp.asarray(rng.standard_normal((b, hq, t, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, ref, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(rng, dtype):
    q = jnp.asarray(rng.standard_normal((1, 2, 64, 32)), dtype)
    k = jnp.asarray(rng.standard_normal((1, 2, 64, 32)), dtype)
    v = jnp.asarray(rng.standard_normal((1, 2, 64, 32)), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    ref = attention_ref(q, k, v, causal=True)
    atol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol)


def test_decode_matches_prefix_attention(rng):
    b, hq, hkv, d, S, L = 2, 4, 2, 32, 64, 40
    kc = jnp.asarray(rng.standard_normal((b, hkv, S, d)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((b, hkv, S, d)), jnp.float32)
    q1 = jnp.asarray(rng.standard_normal((b, hq, 1, d)), jnp.float32)
    out = decode_attention(q1, kc, vc, L)
    ref = attention_ref(q1, kc[:, :, :L], vc[:, :, :L], causal=False)
    np.testing.assert_allclose(out, ref, atol=2e-5)


@pytest.mark.parametrize("t,e,cap", [(100, 8, 16), (2048, 64, 64),
                                     (513, 16, 8), (5, 3, 2)])
def test_dispatch_sweep(rng, t, e, cap):
    dest = rng.integers(-1, e, t).astype(np.int32)
    r1, c1 = dispatch_ranks(jnp.asarray(dest), e)
    r2, c2 = dispatch_ranks_ref(jnp.asarray(dest), e)
    assert np.array_equal(np.asarray(r1), np.asarray(r2))
    assert np.array_equal(np.asarray(c1), np.asarray(c2))
    vals = rng.standard_normal((t, 4)).astype(np.float32)
    b1, cc1, o1 = dispatch_to_buckets(jnp.asarray(vals), jnp.asarray(dest), e, cap)
    b2, cc2, o2 = dispatch_to_buckets_ref(jnp.asarray(vals), jnp.asarray(dest), e, cap)
    np.testing.assert_allclose(b1, b2)
    assert int(o1) == int(o2)


from hypothesis import given, settings, strategies as st


@given(st.integers(1, 300), st.integers(1, 12), st.integers(0, 3))
@settings(max_examples=40, deadline=None)
def test_dispatch_rank_property(t, e, seed):
    """Ranks within each destination are exactly 0..count-1 (a permutation)."""
    rng = np.random.default_rng(seed)
    dest = rng.integers(0, e, t).astype(np.int32)
    r, c = dispatch_ranks(jnp.asarray(dest), e)
    r, c = np.asarray(r), np.asarray(c)
    for g in range(e):
        ranks = np.sort(r[dest == g])
        assert np.array_equal(ranks, np.arange(len(ranks)))
    assert np.array_equal(np.bincount(dest, minlength=e), c)
