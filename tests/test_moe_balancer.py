"""MoE layer correctness (both strategies), balancer, capacity semantics."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core.balancer import (ExpertBalancer, placement_from_assignment,
                                 schedule_balanced_cardinality)
from repro.nn import layers as L
from repro.nn.moe import MoEArgs, init_moe, moe


def _dense_oracle(params, x, top_k, gated=True, act="silu"):
    xf = np.asarray(x).reshape(-1, x.shape[-1])
    logits = xf @ np.asarray(params["router"]["w"])
    e_x = np.exp(logits - logits.max(-1, keepdims=True))
    probs = e_x / e_x.sum(-1, keepdims=True)
    out = np.zeros_like(xf)
    for t in range(xf.shape[0]):
        top = np.argsort(-probs[t])[:top_k]
        w = probs[t][top]
        w = w / w.sum()
        for kk, e in enumerate(top):
            h = np.asarray(jax.nn.silu(
                xf[t] @ params["gate"]["w"][e])) * (
                xf[t] @ np.asarray(params["up"]["w"][e]))
            out[t] += w[kk] * (h @ np.asarray(params["down"]["w"][e]))
    return out


@pytest.mark.parametrize("strategy,E", [("a2a", 8), ("broadcast", 8),
                                        ("broadcast", 6)])
def test_moe_matches_dense_oracle(mesh8, strategy, E):
    args = MoEArgs(num_experts=E, top_k=2, d_model=16, d_ff=32,
                   capacity_factor=8.0, strategy=strategy)
    params, _ = L.split(init_moe(jax.random.PRNGKey(0), args, mesh8))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16))
    y, stats = moe(params, x, args=args, mesh=mesh8)
    oracle = _dense_oracle(params, x, 2)
    np.testing.assert_allclose(np.asarray(y).reshape(-1, 16), oracle,
                               atol=1e-4)
    assert int(stats["overflow"]) == 0
    assert float(stats["counts"].sum()) == 4 * 16 * 2


def test_moe_single_device_fallback():
    """Trivial 1x1 mesh path used by CPU smoke tests."""
    args = MoEArgs(num_experts=4, top_k=2, d_model=8, d_ff=16,
                   capacity_factor=8.0)
    params, _ = L.split(init_moe(jax.random.PRNGKey(0), args, None))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8))
    y, stats = moe(params, x, args=args, mesh=None)
    oracle = _dense_oracle(params, x, 2)
    np.testing.assert_allclose(np.asarray(y).reshape(-1, 8), oracle, atol=1e-4)


def test_capacity_drops_counted(mesh8):
    """Tiny capacity must drop tokens and report overflow, not corrupt."""
    args = MoEArgs(num_experts=8, top_k=2, d_model=16, d_ff=32,
                   capacity_factor=8.0, strategy="a2a")
    params, _ = L.split(init_moe(jax.random.PRNGKey(0), args, mesh8))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16))
    y, stats = moe(params, x, args=args, mesh=mesh8, capacity=8)
    assert bool(jnp.isfinite(y).all())
    assert int(stats["overflow"]) >= 0


class TestBalancer:
    @given(st.integers(0, 10))
    @settings(max_examples=20, deadline=None)
    def test_cardinality_constraint(self, seed):
        rng = np.random.default_rng(seed)
        loads = rng.zipf(1.5, 32).astype(float)
        a = schedule_balanced_cardinality(loads, 4, 8)
        assert (np.bincount(a, minlength=4) == 8).all()

    @given(st.integers(0, 10))
    @settings(max_examples=20, deadline=None)
    def test_never_worse_than_contiguous(self, seed):
        rng = np.random.default_rng(seed)
        loads = rng.zipf(1.5, 32).astype(float)
        a = schedule_balanced_cardinality(loads, 4, 8)
        got = np.bincount(a, weights=loads, minlength=4).max()
        base = np.bincount(np.arange(32) // 8, weights=loads,
                           minlength=4).max()
        assert got <= base + 1e-9

    def test_placement_consistent_with_perm(self):
        rng = np.random.default_rng(0)
        loads = rng.random(16)
        a = schedule_balanced_cardinality(loads, 4, 4)
        placement, perm = placement_from_assignment(a, 4)
        for g, e in enumerate(perm):
            assert placement[0, e] * 4 + placement[1, e] == g

    def test_replan_improves_hot_expert_layout(self):
        b = ExpertBalancer(8, 4, 1, interval=1)
        counts = np.array([[100, 1, 1, 1, 100, 1, 1, 1]], float)
        # contiguous baseline puts both hot experts' shards unevenly? here
        # experts 0 and 4 are on shards 0 and 2 — replan must not regress.
        b.observe(counts)
        _, _, reports = b.replan()
        assert reports[0].balance_ratio <= reports[0].baseline_ratio + 1e-9

    def test_drift_gate_keeps_placement_on_steady_routing(self):
        """max_drift: a layer whose routing didn't move skips the re-solve
        and keeps its placement; a shifted layer still replans."""
        b = ExpertBalancer(8, 4, 1, interval=1, ema=0.0, max_drift=0.1)
        hot = np.array([[100, 1, 1, 1, 100, 1, 1, 1]], float)
        b.observe(hot)
        p1, perms1, _ = b.replan()
        assert b.layers_replanned == 1
        b.observe(hot * 3.0)            # same shape, bigger batch: no drift
        p2, perms2, reports = b.replan()
        assert b.layers_reused == 1 and b.layers_replanned == 1
        assert np.array_equal(p1, p2)
        assert np.array_equal(perms1[0], perms2[0])
        assert reports[0].moved_experts == 0
        b.observe(hot[:, ::-1].copy())  # routing flipped: drift > 0.1
        _, _, _ = b.replan()
        assert b.layers_replanned == 2
        # regression: the reuse interval must have returned COPIES — a
        # later in-place replan of self.perms must not mutate the perm the
        # trainer holds as "previous physical order".
        assert np.array_equal(perms2[0], perms1[0])


class TestBalancerSpeeds:
    """Q||C_max expert placement (ISSUE 4 tentpole part 3)."""

    @given(st.integers(0, 10))
    @settings(max_examples=20, deadline=None)
    def test_none_and_ones_identical(self, seed):
        """speeds=None keeps the P||C_max code path bit-for-bit, and an
        explicit all-ones vector lands on the same assignment."""
        rng = np.random.default_rng(seed)
        loads = rng.zipf(1.5, 32).astype(float)
        a_none = schedule_balanced_cardinality(loads, 4, 8)
        a_ones = schedule_balanced_cardinality(loads, 4, 8,
                                               speeds=np.ones(4))
        assert np.array_equal(a_none, a_ones)

    @given(st.integers(0, 10))
    @settings(max_examples=20, deadline=None)
    def test_cardinality_holds_under_speeds(self, seed):
        rng = np.random.default_rng(seed)
        loads = rng.zipf(1.5, 32).astype(float)
        speeds = rng.uniform(0.3, 1.5, size=4)
        a = schedule_balanced_cardinality(loads, 4, 8, speeds=speeds)
        assert (np.bincount(a, minlength=4) == 8).all()

    def test_speed_aware_strictly_beats_p_placement(self):
        """Acceptance fixture: skewed zipf expert loads, one EP shard at
        0.5x. The Q||C_max placement's estimated makespan (finish time
        under the true speeds) is STRICTLY below pricing the P||C_max
        placement under those speeds."""
        rng = np.random.default_rng(0)
        # clip keeps any single expert from dominating the makespan on its
        # own (a lone huge operation pins both placements to the same
        # bound); here aggregate balance governs, where speeds matter.
        loads = rng.zipf(1.4, 64).clip(1, 800).astype(float)
        speeds = np.ones(8)
        speeds[3] = 0.5
        a_p = schedule_balanced_cardinality(loads, 8, 8)
        a_q = schedule_balanced_cardinality(loads, 8, 8, speeds=speeds)
        mk_p = (np.bincount(a_p, weights=loads, minlength=8) / speeds).max()
        mk_q = (np.bincount(a_q, weights=loads, minlength=8) / speeds).max()
        assert mk_q < mk_p

    def test_speeds_validation(self):
        loads = np.arange(8, dtype=float)
        with pytest.raises(ValueError):
            schedule_balanced_cardinality(loads, 4, 2, speeds=np.ones(3))
        with pytest.raises(ValueError):
            schedule_balanced_cardinality(loads, 4, 2,
                                          speeds=[1.0, -0.5, 1.0, 1.0])
        with pytest.raises(ValueError):
            schedule_balanced_cardinality(loads, 4, 2, speeds=np.zeros(4))

    def test_dead_device_gets_coldest_experts(self):
        # Speed exactly 0.0 = dead (elastic mesh). The cardinality
        # constraint still forces every device to hold its quota of
        # experts, so a dead device ends up with the *coldest* ones —
        # its load is minimal, never the makespan.
        loads = np.array([60, 50, 40, 30, 20, 10, 5, 5], float)
        assignment = schedule_balanced_cardinality(
            loads, 4, 2, speeds=[1.0, 0.0, 1.0, 1.0])
        per_dev = np.bincount(assignment, weights=loads, minlength=4)
        assert np.bincount(assignment, minlength=4).tolist() == [2] * 4
        assert per_dev[1] == pytest.approx(per_dev.min())

    def test_balancer_reports_finish_metrics_and_reacts_to_speeds(self):
        speeds = np.asarray([1.0, 1.0, 0.5, 1.0])
        b = ExpertBalancer(8, 4, 1, interval=1, ema=0.0, speeds=speeds)
        hot = np.array([[60, 50, 40, 30, 20, 10, 5, 5]], float)
        b.observe(hot)
        _, _, reports = b.replan()
        r = reports[0]
        loads = np.bincount(b._assignments[0], weights=hot[0], minlength=4)
        assert r.makespan == pytest.approx((loads / speeds).max())
        assert r.finish_ratio >= 1.0
        # the same counts under a P||C_max balancer finish no sooner
        bp = ExpertBalancer(8, 4, 1, interval=1, ema=0.0)
        bp.observe(hot)
        bp.replan()
        loads_p = np.bincount(bp._assignments[0], weights=hot[0], minlength=4)
        assert r.makespan <= (loads_p / speeds).max() + 1e-9
        # nominal speeds: finish metrics coincide with load metrics
        assert bp.replan()[2][0].makespan == pytest.approx(
            np.bincount(bp._assignments[0], weights=bp.counts[0],
                        minlength=4).max())

    def test_set_speeds_invalidates_drift_baseline(self):
        """Changed speeds must force a re-solve even under max_drift gating
        with perfectly steady routing."""
        b = ExpertBalancer(8, 4, 1, interval=1, ema=0.0, max_drift=0.1)
        hot = np.array([[100, 1, 1, 1, 100, 1, 1, 1]], float)
        b.observe(hot)
        b.replan()
        b.observe(hot)
        b.replan()
        assert b.layers_reused == 1          # steady routing -> reuse
        b.set_speeds([1.0, 0.25, 1.0, 1.0])
        b.observe(hot)
        b.replan()
        assert b.layers_replanned == 2       # speeds changed -> re-solve
        with pytest.raises(ValueError):
            b.set_speeds([1.0, -1.0, 1.0, 1.0])

    def test_balanced_placement_helper(self, mesh8):
        """nn.moe.balanced_placement threads speeds end to end and stays
        consistent with the weight-row permutation contract."""
        from repro.nn.moe import balanced_placement

        args = MoEArgs(num_experts=8, top_k=2, d_model=16, d_ff=32)
        counts = np.asarray([60, 50, 40, 30, 20, 10, 5, 5], float)
        m = args.ep_size(mesh8)
        per = args.experts_per_shard(mesh8)
        placement, perm = balanced_placement(args, mesh8, counts)
        for g, e in enumerate(perm):
            assert int(placement[0, e]) * per + int(placement[1, e]) == g
        speeds = np.ones(m)
        speeds[0] = 0.5
        placement_q, _ = balanced_placement(args, mesh8, counts,
                                            speeds=speeds)
        loads_p = np.bincount(np.asarray(placement[0]), weights=counts,
                              minlength=m)
        loads_q = np.bincount(np.asarray(placement_q[0]), weights=counts,
                              minlength=m)
        assert (loads_q / speeds).max() <= (loads_p / speeds).max() + 1e-9


def test_moe_respects_balanced_placement(mesh8):
    """A replanned placement yields identical outputs (pure relabeling)."""
    from repro.core.balancer import permute_expert_weights

    args = MoEArgs(num_experts=8, top_k=2, d_model=16, d_ff=32,
                   capacity_factor=8.0, strategy="a2a")
    params, _ = L.split(init_moe(jax.random.PRNGKey(0), args, mesh8))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16))
    y0, _ = moe(params, x, args=args, mesh=mesh8)

    # a random permutation placement + correspondingly permuted weights
    rng = np.random.default_rng(0)
    assignment = np.repeat(np.arange(4), 2)
    rng.shuffle(assignment)
    placement, perm = placement_from_assignment(assignment, 4)
    pp = dict(params)
    pp.update(permute_expert_weights(
        {k: params[k] for k in ("up", "gate", "down")}, perm))
    y1, _ = moe(pp, x, args=args, mesh=mesh8,
                placement=jnp.asarray(placement))
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-4)
