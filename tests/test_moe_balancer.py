"""MoE layer correctness (both strategies), balancer, capacity semantics."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core.balancer import (ExpertBalancer, placement_from_assignment,
                                 schedule_balanced_cardinality)
from repro.nn import layers as L
from repro.nn.moe import MoEArgs, init_moe, moe


def _dense_oracle(params, x, top_k, gated=True, act="silu"):
    xf = np.asarray(x).reshape(-1, x.shape[-1])
    logits = xf @ np.asarray(params["router"]["w"])
    e_x = np.exp(logits - logits.max(-1, keepdims=True))
    probs = e_x / e_x.sum(-1, keepdims=True)
    out = np.zeros_like(xf)
    for t in range(xf.shape[0]):
        top = np.argsort(-probs[t])[:top_k]
        w = probs[t][top]
        w = w / w.sum()
        for kk, e in enumerate(top):
            h = np.asarray(jax.nn.silu(
                xf[t] @ params["gate"]["w"][e])) * (
                xf[t] @ np.asarray(params["up"]["w"][e]))
            out[t] += w[kk] * (h @ np.asarray(params["down"]["w"][e]))
    return out


@pytest.mark.parametrize("strategy,E", [("a2a", 8), ("broadcast", 8),
                                        ("broadcast", 6)])
def test_moe_matches_dense_oracle(mesh8, strategy, E):
    args = MoEArgs(num_experts=E, top_k=2, d_model=16, d_ff=32,
                   capacity_factor=8.0, strategy=strategy)
    params, _ = L.split(init_moe(jax.random.PRNGKey(0), args, mesh8))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16))
    y, stats = moe(params, x, args=args, mesh=mesh8)
    oracle = _dense_oracle(params, x, 2)
    np.testing.assert_allclose(np.asarray(y).reshape(-1, 16), oracle,
                               atol=1e-4)
    assert int(stats["overflow"]) == 0
    assert float(stats["counts"].sum()) == 4 * 16 * 2


def test_moe_single_device_fallback():
    """Trivial 1x1 mesh path used by CPU smoke tests."""
    args = MoEArgs(num_experts=4, top_k=2, d_model=8, d_ff=16,
                   capacity_factor=8.0)
    params, _ = L.split(init_moe(jax.random.PRNGKey(0), args, None))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8))
    y, stats = moe(params, x, args=args, mesh=None)
    oracle = _dense_oracle(params, x, 2)
    np.testing.assert_allclose(np.asarray(y).reshape(-1, 8), oracle, atol=1e-4)


def test_capacity_drops_counted(mesh8):
    """Tiny capacity must drop tokens and report overflow, not corrupt."""
    args = MoEArgs(num_experts=8, top_k=2, d_model=16, d_ff=32,
                   capacity_factor=8.0, strategy="a2a")
    params, _ = L.split(init_moe(jax.random.PRNGKey(0), args, mesh8))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16))
    y, stats = moe(params, x, args=args, mesh=mesh8, capacity=8)
    assert bool(jnp.isfinite(y).all())
    assert int(stats["overflow"]) >= 0


class TestBalancer:
    @given(st.integers(0, 10))
    @settings(max_examples=20, deadline=None)
    def test_cardinality_constraint(self, seed):
        rng = np.random.default_rng(seed)
        loads = rng.zipf(1.5, 32).astype(float)
        a = schedule_balanced_cardinality(loads, 4, 8)
        assert (np.bincount(a, minlength=4) == 8).all()

    @given(st.integers(0, 10))
    @settings(max_examples=20, deadline=None)
    def test_never_worse_than_contiguous(self, seed):
        rng = np.random.default_rng(seed)
        loads = rng.zipf(1.5, 32).astype(float)
        a = schedule_balanced_cardinality(loads, 4, 8)
        got = np.bincount(a, weights=loads, minlength=4).max()
        base = np.bincount(np.arange(32) // 8, weights=loads,
                           minlength=4).max()
        assert got <= base + 1e-9

    def test_placement_consistent_with_perm(self):
        rng = np.random.default_rng(0)
        loads = rng.random(16)
        a = schedule_balanced_cardinality(loads, 4, 4)
        placement, perm = placement_from_assignment(a, 4)
        for g, e in enumerate(perm):
            assert placement[0, e] * 4 + placement[1, e] == g

    def test_replan_improves_hot_expert_layout(self):
        b = ExpertBalancer(8, 4, 1, interval=1)
        counts = np.array([[100, 1, 1, 1, 100, 1, 1, 1]], float)
        # contiguous baseline puts both hot experts' shards unevenly? here
        # experts 0 and 4 are on shards 0 and 2 — replan must not regress.
        b.observe(counts)
        _, _, reports = b.replan()
        assert reports[0].balance_ratio <= reports[0].baseline_ratio + 1e-9

    def test_drift_gate_keeps_placement_on_steady_routing(self):
        """max_drift: a layer whose routing didn't move skips the re-solve
        and keeps its placement; a shifted layer still replans."""
        b = ExpertBalancer(8, 4, 1, interval=1, ema=0.0, max_drift=0.1)
        hot = np.array([[100, 1, 1, 1, 100, 1, 1, 1]], float)
        b.observe(hot)
        p1, perms1, _ = b.replan()
        assert b.layers_replanned == 1
        b.observe(hot * 3.0)            # same shape, bigger batch: no drift
        p2, perms2, reports = b.replan()
        assert b.layers_reused == 1 and b.layers_replanned == 1
        assert np.array_equal(p1, p2)
        assert np.array_equal(perms1[0], perms2[0])
        assert reports[0].moved_experts == 0
        b.observe(hot[:, ::-1].copy())  # routing flipped: drift > 0.1
        _, _, _ = b.replan()
        assert b.layers_replanned == 2
        # regression: the reuse interval must have returned COPIES — a
        # later in-place replan of self.perms must not mutate the perm the
        # trainer holds as "previous physical order".
        assert np.array_equal(perms2[0], perms1[0])


def test_moe_respects_balanced_placement(mesh8):
    """A replanned placement yields identical outputs (pure relabeling)."""
    from repro.core.balancer import permute_expert_weights

    args = MoEArgs(num_experts=8, top_k=2, d_model=16, d_ff=32,
                   capacity_factor=8.0, strategy="a2a")
    params, _ = L.split(init_moe(jax.random.PRNGKey(0), args, mesh8))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16))
    y0, _ = moe(params, x, args=args, mesh=mesh8)

    # a random permutation placement + correspondingly permuted weights
    rng = np.random.default_rng(0)
    assignment = np.repeat(np.arange(4), 2)
    rng.shuffle(assignment)
    placement, perm = placement_from_assignment(assignment, 4)
    pp = dict(params)
    pp.update(permute_expert_weights(
        {k: params[k] for k in ("up", "gate", "down")}, perm))
    y1, _ = moe(pp, x, args=args, mesh=mesh8,
                placement=jnp.asarray(placement))
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-4)
