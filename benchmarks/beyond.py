"""Beyond-paper benchmarks: MoE expert balance, packing, lane scheduling."""

from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core import scheduler as S
from repro.core.balancer import schedule_balanced_cardinality

Row = Tuple[str, str, float]


def moe_balance() -> List[Row]:
    """Required per-shard capacity (= scheduled max-load) vs placement
    policy, for deepseek-class expert-load skew. Capacity is the compiled
    dispatch-buffer size: smaller capacity = less padded compute, memory,
    and a2a bytes — the OS4M win in static-shape terms."""
    rows: List[Row] = []
    rng = np.random.default_rng(0)
    E, m, per = 160, 16, 10
    for skew, alpha in [("mild", 0.6), ("heavy", 1.1)]:
        w = np.arange(1, E + 1, dtype=np.float64) ** (-alpha)
        rng.shuffle(w)
        loads = w / w.sum() * 1.57e6  # deepseek train_4k tokens*topk/row
        ideal = loads.sum() / m
        base = np.bincount(np.arange(E) // per, weights=loads, minlength=m)
        bal = schedule_balanced_cardinality(loads, m, per)
        bl = np.bincount(bal, weights=loads, minlength=m)
        rows.append((f"moe_{skew}", "contiguous_capacity_ratio",
                     float(base.max() / ideal)))
        rows.append((f"moe_{skew}", "os4m_capacity_ratio",
                     float(bl.max() / ideal)))
        rows.append((f"moe_{skew}", "padded_compute_saving_pct",
                     100 * (1 - bl.max() / base.max())))
    return rows


def packing_bench() -> List[Row]:
    """Token efficiency of OS4M packing vs round-robin baseline."""
    from repro.data import packing

    rng = np.random.default_rng(0)
    docs = [np.ones(int(l), np.int32)
            for l in np.clip(rng.lognormal(5.0, 1.0, 2000), 8, 4096)]
    rows: List[Row] = []
    for sched in ["hash", "lpt", "os4m"]:
        t0 = time.perf_counter()
        _, stats = packing.pack_documents(docs, 64, 2048, scheduler=sched)
        dt = time.perf_counter() - t0
        rows.append(("packing", f"{sched}_efficiency", stats.efficiency))
        rows.append(("packing", f"{sched}_time_s", dt))
    return rows


def lane_scheduling() -> List[Row]:
    """Serving lane balance: OS4M vs hash admission over skewed budgets."""
    rows: List[Row] = []
    rng = np.random.default_rng(0)
    loads = rng.zipf(1.35, 512).clip(1, 2048).astype(float)
    for name in ["hash", "lpt", "os4m"]:
        if name == "hash":
            sched = S.schedule_hash(loads, 64, keys=np.arange(512))
        elif name == "lpt":
            sched = S.schedule_lpt(loads, 64)
        else:
            sched = S.schedule_bss(loads, 64)
        rows.append(("lanes", f"{name}_balance_ratio", sched.balance_ratio))
        rows.append(("lanes", f"{name}_p95_over_ideal", float(
            np.percentile(sched.slot_loads, 95)
            / (loads.sum() / 64))))
    return rows


def scheduler_scaling() -> List[Row]:
    """BSS runtime vs instance size (paper Fig 10 claim of scalability)."""
    rows: List[Row] = []
    rng = np.random.default_rng(0)
    for n in [240, 960, 3840]:
        loads = rng.zipf(1.3, n).astype(float)
        t0 = time.perf_counter()
        S.schedule_bss(loads, 256)
        rows.append(("sched_scale", f"n{n}_m256_s",
                     time.perf_counter() - t0))
    return rows


ALL_BEYOND = [moe_balance, packing_bench, lane_scheduling, scheduler_scaling]
