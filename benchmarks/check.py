"""CI gate checks over the bench JSON reports.

One place for every pass/fail threshold the workflow enforces, instead
of five inline heredoc scripts scattered through ci.yml::

    python -m benchmarks.check --gate smoke
    python -m benchmarks.check --gate elastic --path BENCH_elastic.json

Each gate reads the JSON report its bench leg wrote (default path per
gate, overridable with ``--path``), asserts the thresholds through one
helper — :func:`require`, which prints the gate name, the threshold,
and the actual value on failure — and prints a short human summary on
success.  The ``docs-links`` gate takes no JSON; it walks the repo's
markdown instead.

Exit status is the contract: 0 = gate passed, 1 = gate failed.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import re
import sys
from typing import Callable, Dict, List, Optional


class GateFailure(AssertionError):
    """A gate threshold was not met (message carries gate/threshold/actual)."""


def require(gate: str, condition: bool, threshold: str, actual) -> None:
    """Assert one gate condition.

    On failure raises :class:`GateFailure` with a message naming the
    *gate*, the *threshold* that was violated, and the *actual* value —
    so a red CI leg is diagnosable from the one-line summary alone.
    """
    if not condition:
        raise GateFailure(
            f"[gate {gate}] FAIL: expected {threshold}, actual {actual!r}")


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


# ---------------------------------------------------------------------------
# gates (one function per CI leg)
# ---------------------------------------------------------------------------

def gate_smoke(path: str = "BENCH_schedulers.json") -> None:
    """Perf-trajectory smoke: pipelined output identity + balance table."""
    r = _load(path)
    eng = r["engine"]
    require("smoke", eng["bit_identical"],
            "pipelined == sequential outputs", eng["bit_identical"])
    for name, row in r["schedulers"].items():
        print(f"{name}: balance_ratio={row['balance_ratio']:.4f}")
    print(f"sequential={eng['sequential_seconds']:.3f}s "
          f"pipelined={eng['pipelined_seconds']:.3f}s "
          f"speedup={eng['speedup']:.2f}x")


def gate_reuse(path: str = "BENCH_schedule_reuse.json") -> None:
    """Schedule-reuse steady state: identity, one cold plan, drift replans."""
    r = _load(path)
    require("reuse", r["bit_identical"],
            "reused schedule == always-replan outputs", r["bit_identical"])
    require("reuse", r["stationary_replans"] == 1,
            "stationary_replans == 1", r["stationary_replans"])
    require("reuse", r["drift_replans"] >= 1,
            "drift_replans >= 1 (injected drift must replan)",
            r["drift_replans"])
    print(f"replan_rate={r['replan_rate']:.3f} "
          f"steady={r['steady_state_seconds']*1e3:.1f}ms "
          f"always-replan={r['always_replan_seconds']*1e3:.1f}ms "
          f"speedup={r['speedup']:.2f}x")


def _straggler_common(gate: str, r: dict) -> None:
    require(gate, r["bit_identical"],
            "speed-aware outputs == oblivious outputs", r["bit_identical"])
    require(gate, r["min_makespan_cut"] >= 0.25,
            "min_makespan_cut >= 0.25", r["min_makespan_cut"])
    require(gate, r["speed_replans"] >= 1,
            "speed_replans >= 1 (slowdown detected online)",
            r["speed_replans"])


def gate_straggler(path: str = "BENCH_stragglers.json") -> None:
    """Q||C_max straggler sweep with the synthetic timing model."""
    r = _load(path)
    _straggler_common("straggler", r)
    for name, row in r["strategies"].items():
        print(f"{name}: cut={row['makespan_cut']*100:.1f}% "
              f"finish_ratio={row['aware_finish_ratio']:.3f}")
    print(f"speed_replans={r['speed_replans']} "
          f"final_speeds={r['estimated_final_speeds']}")


def gate_straggler_measured(path: str = "BENCH_stragglers_measured.json",
                            overlap_path: str = "BENCH_overlap_measured.json",
                            ) -> None:
    """Straggler gates on MEASURED wave clocks + overlap-recovery gate."""
    r = _load(path)
    require("straggler-measured", r["timing_source"].startswith("measured"),
            'timing_source startswith "measured"', r["timing_source"])
    require("straggler-measured", r["measured_batches"] >= 1,
            "measured_batches >= 1", r["measured_batches"])
    _straggler_common("straggler-measured", r)
    print(f"measured_batches={r['measured_batches']} "
          f"speed_replans={r['speed_replans']} "
          f"final_speeds={r['estimated_final_speeds']}")
    ov = _load(overlap_path)
    require("straggler-measured", ov["overlap_recovered"],
            "overlap_recovered (measured phase B within threshold "
            "of unmeasured)", ov["measured_over_unmeasured"])
    print(f"overlap: measured/unmeasured="
          f"{ov['measured_over_unmeasured']:.2f} "
          f"fenced/unmeasured={ov['fenced_over_unmeasured']:.2f}")


def gate_elastic(path: str = "BENCH_elastic.json") -> None:
    """Elastic-mesh fault injection: identity, bounded replay, dead loads."""
    r = _load(path)
    require("elastic", r["bit_identical"],
            "all fault scenarios bit-identical to uninterrupted run",
            {k: r[k]["bit_identical"] for k in ("dead_at_start",
                                                "die_mid_wave")}
            | {"resize_8": r["resizes"]["outputs_8_bit_identical"]})
    require("elastic", r["dead_at_start"]["dead_slot_load"] == 0.0,
            "dead-at-start slot load == 0", r["dead_at_start"])
    mk = r["die_mid_wave"]
    require("elastic", mk["replay_bound_ok"],
            "replayed_waves <= num_waves - checkpoint_wave",
            (mk["replayed_waves"], mk["num_waves"], mk["checkpoint_wave"]))
    require("elastic", mk["replay_dead_slot_load"] == 0.0,
            "recovery plan assigns dead slot zero load",
            mk["replay_dead_slot_load"])
    rs = r["resizes"]
    require("elastic", rs["no_cold_after_resize"],
            'post-resize plan_reason != "cold" (snapshot re-projected)',
            (rs["after_8to6_reason"], rs["after_6to8_reason"]))
    require("elastic", rs["reprojections"] >= 2,
            "reprojections >= 2 (both resizes warm)", rs["reprojections"])
    require("elastic", rs["outputs_6_match"],
            "6-slot outputs match dedicated 6-slot job",
            rs["outputs_6_match"])
    print(f"dead-at-start load={r['dead_at_start']['dead_slot_load']} "
          f"mid-kill ckpt={mk['checkpoint_wave']}/{mk['num_waves']} "
          f"replayed={mk['replayed_waves']} "
          f"reprojections={rs['reprojections']}")


def gate_multijob(path: str = "BENCH_multijob.json") -> None:
    """Multi-job R||C_max: ΣwC improvement, bit-identity, tenant isolation."""
    r = _load(path)
    require("multijob", r["improvement"] >= 0.20,
            "WSPT admission improves ΣwC by >= 20% over FIFO",
            f"{r['improvement'] * 100:.1f}%")
    require("multijob", r["bit_identical"],
            "coordinator-run outputs == solo-job outputs",
            r["bit_identical"])
    require("multijob", r["cache"]["collisions"] == 0,
            "zero cross-tenant schedule-cache collisions",
            r["cache"]["collisions"])
    require("multijob", r["cache"]["tenants"] >= 2,
            "at least 2 live tenants measured", r["cache"]["tenants"])
    require("multijob", r["wspt"]["order"][0] == "urgent",
            "Smith's rule admits the heavy short job first",
            r["wspt"]["order"])
    print(f"ΣwC fifo={r['fifo']['weighted_completion_s']:.3f}s "
          f"wspt={r['wspt']['weighted_completion_s']:.3f}s "
          f"improvement={r['improvement'] * 100:.1f}% "
          f"overlap={r['coschedule_overlap']:.2f} "
          f"collisions={r['cache']['collisions']}")


def gate_shuffle_volume(path: str = "BENCH_shuffle_volume.json") -> None:
    """Coded shuffle: measured wire-byte cut, bit-identity, bounded wall.

    ``wall_ok`` is computed by the bench (factor + absolute CPU-compute
    allowance — see ``SHUFFLE_WALL_FACTOR`` in benchmarks/run.py); the
    gate asserts the verdict and prints the raw ratio for the record.
    """
    r = _load(path)
    require("shuffle-volume", r["bit_identical"],
            "coded (r=2) outputs == uncoded outputs", r["bit_identical"])
    require("shuffle-volume", r["bytes_reduction"] >= 1.5,
            "measured wire bytes cut >= 1.5x at r=2",
            f"{r['bytes_reduction']:.2f}x")
    require("shuffle-volume", r["wall_ok"],
            "coded wall clock within factor+slack of uncoded",
            f"x{r['wall_ratio']:.2f}")
    require("shuffle-volume", r["coded"]["replication_bytes"] > 0,
            "replica-exchange bytes accounted separately (> 0)",
            r["coded"]["replication_bytes"])
    require("shuffle-volume", r["quantized"]["bit_identical"],
            "coded int8 outputs == uncoded int8 outputs",
            r["quantized"]["bit_identical"])
    print(f"wire bytes {r['uncoded']['shuffle_bytes']} -> "
          f"{r['coded']['shuffle_bytes']} "
          f"({r['bytes_reduction']:.2f}x) + "
          f"{r['coded']['replication_bytes']} replica B, "
          f"wall x{r['wall_ratio']:.2f}, "
          f"int8 {r['quantized']['uncoded_bytes']} -> "
          f"{r['quantized']['coded_bytes']} B")


def gate_sketch(path: str = "BENCH_sketch.json") -> None:
    """Pluggable statistics: sketch plan-path cut, hatch rate, identity.

    The plan-path speedup threshold (1.3x) sits well under the measured
    in-container margin (~2.5x at 2**17 clusters) because shared 2-core
    CI runners time noisily; the structural pull-size cut is asserted
    exactly — it is deterministic.
    """
    r = _load(path)
    require("sketch", r["bit_identical"],
            "sketch + prefix-planned outputs == exact outputs",
            r["bit_identical"])
    pp = r["plan_path"]
    require("sketch", pp["sketch_pull_floats"] < pp["exact_pull_floats"],
            "sketch device->host pull smaller than exact histogram pull",
            f"{pp['sketch_pull_floats']} vs {pp['exact_pull_floats']}")
    require("sketch", pp["speedup"] >= 1.3,
            "plan-path speedup >= 1.3x at large key counts",
            f"{pp['speedup']:.2f}x")
    benign = r["scenarios"]["benign"]
    adv = r["scenarios"]["adversarial"]
    require("sketch", benign["overflow_replans"] == 0,
            "benign stream trips no overflow hatch",
            benign["overflow_replans"])
    require("sketch", adv["overflow_replans"] >= 1,
            "adversarial stream trips the overflow hatch >= 1x",
            adv["overflow_replans"])
    require("sketch", benign["overflow_free"] and adv["overflow_free"],
            "all streamed batches finish with zero overflow",
            (benign["overflow_free"], adv["overflow_free"]))
    print(f"plan path {pp['exact_seconds']*1e3:.1f}ms -> "
          f"{pp['sketch_seconds']*1e3:.1f}ms ({pp['speedup']:.2f}x), "
          f"pull {pp['exact_pull_floats']} -> {pp['sketch_pull_floats']} "
          f"floats, hatch benign={benign['overflow_replans']}"
          f"/{benign['batches']} adversarial={adv['overflow_replans']}"
          f"/{adv['batches']}")


def gate_docs_links(root: str = ".") -> None:
    """Walk repo markdown; every relative ``.md``/``.py`` link must exist."""
    bad: List[str] = []
    for md in pathlib.Path(root).rglob("*.md"):
        if ".git" in md.parts or md.name == "SNIPPETS.md":
            continue
        for target in re.findall(r"\]\(([^)#]+?)(?:#[^)]*)?\)",
                                 md.read_text()):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            if not target.endswith((".md", ".py")):
                continue   # badges / GitHub-relative app links
            if not (md.parent / target).exists():
                bad.append(f"{md}: broken link -> {target}")
    require("docs-links", not bad, "no broken relative links",
            "\n".join(bad) or "ok")
    print("docs links ok")


def gate_static_analysis(check: str = "all") -> None:
    """Static contract analyzer: all checkers + mutation self-tests green.

    Runs ``repro.analysis`` in-process (same interpreter as the suite) on
    the repo's real traced phase-B variants and planner snapshots, with
    the mutation self-tests on — so CI fails both when a contract is
    violated *and* when a checker goes blind. The asserted value is the
    analyzer's exit bitmask (overlap 1, determinism 2, plan 4,
    conventions 8, self-test 16), which names the failing layer.
    """
    from repro.analysis import run as run_analysis

    code = run_analysis(check=check, self_test=True)
    require("static-analysis", code == 0,
            "repro.analysis exit bitmask == 0 "
            "(overlap 1 | determinism 2 | plan 4 | conventions 8 | "
            "self-test 16)", code)


GATES: Dict[str, Callable[..., None]] = {
    "smoke": gate_smoke,
    "static-analysis": gate_static_analysis,
    "reuse": gate_reuse,
    "straggler": gate_straggler,
    "straggler-measured": gate_straggler_measured,
    "elastic": gate_elastic,
    "multijob": gate_multijob,
    "shuffle-volume": gate_shuffle_volume,
    "sketch": gate_sketch,
    "docs-links": gate_docs_links,
}


def main(argv: Optional[List[str]] = None) -> None:
    """CLI entry point: run one named gate, exit non-zero on failure."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--gate", required=True, choices=sorted(GATES))
    ap.add_argument("--path", default=None,
                    help="override the gate's default report path "
                         "(or repo root for docs-links)")
    args = ap.parse_args(argv)
    fn = GATES[args.gate]
    try:
        fn(args.path) if args.path is not None else fn()
    except GateFailure as exc:
        sys.exit(str(exc))
    except FileNotFoundError as exc:
        sys.exit(f"[gate {args.gate}] missing report: {exc}")
    print(f"[gate {args.gate}] ok")


if __name__ == "__main__":
    main()
