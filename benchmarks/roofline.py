"""Roofline report over the dry-run artifacts (deliverable g).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and
prints the per-(arch × shape × mesh) table: the three terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs, and the step-time lower bound.
"""

from __future__ import annotations

import glob
import json
from pathlib import Path
from typing import List, Tuple

Row = Tuple[str, str, float]


def load_records(dryrun_dir="experiments/dryrun"):
    recs = []
    for f in sorted(glob.glob(str(Path(dryrun_dir) / "*.json"))):
        recs.append(json.loads(Path(f).read_text()))
    return recs


def table(dryrun_dir="experiments/dryrun") -> List[str]:
    recs = [r for r in load_records(dryrun_dir) if r.get("status") == "ok"]
    lines = [
        "arch,shape,mesh,t_compute_s,t_memory_s,t_collective_s,dominant,"
        "useful_flops_ratio,ideal_over_bound,peak_gib"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        t = r["roofline"]
        ideal = r["model_flops_per_chip"] / 197e12
        bound = t["step_time_lower_bound_s"]
        lines.append(
            f"{r['arch']},{r['shape']},{r['mesh']},"
            f"{t['t_compute_s']:.4g},{t['t_memory_s']:.4g},"
            f"{t['t_collective_s']:.4g},{t['dominant']},"
            f"{r['useful_flops_ratio']:.3f},"
            f"{ideal / bound if bound else 0:.3f},"
            f"{r['peak_memory_bytes'] / 2**30:.1f}")
    return lines


def summary_rows(dryrun_dir="experiments/dryrun") -> List[Row]:
    recs = [r for r in load_records(dryrun_dir) if r.get("status") == "ok"]
    rows: List[Row] = []
    if not recs:
        rows.append(("roofline", "cells_ok", 0.0))
        return rows
    rows.append(("roofline", "cells_ok", float(len(recs))))
    doms = {}
    for r in recs:
        doms[r["roofline"]["dominant"]] = doms.get(
            r["roofline"]["dominant"], 0) + 1
    for k, v in doms.items():
        rows.append(("roofline", f"dominant_{k}", float(v)))
    fracs = [r["model_flops_per_chip"] / 197e12
             / max(r["roofline"]["step_time_lower_bound_s"], 1e-12)
             for r in recs if r["kind"] == "train"]
    if fracs:
        rows.append(("roofline", "train_roofline_frac_mean",
                     float(sum(fracs) / len(fracs))))
        rows.append(("roofline", "train_roofline_frac_best", float(max(fracs))))
    # §Perf hillclimb cells (recompiled with beyond-paper settings) live in
    # experiments/perf; report their fractions next to the baselines.
    for r in load_records("experiments/perf"):
        if r.get("status") != "ok":
            continue
        frac = r["model_flops_per_chip"] / 197e12 / max(
            r["roofline"]["step_time_lower_bound_s"], 1e-12)
        rows.append(("roofline_perf",
                     f"{r['arch']}_{r['shape']}_optimized_frac", float(frac)))
    return rows


if __name__ == "__main__":
    print("\n".join(table()))
