"""Paper-figure reproductions. Each ``fig*`` returns a list of CSV rows
``(name, key, value)`` and is invoked by benchmarks.run."""

from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core import clustering, scheduler as S
from repro.core.simulator import (PAPER_CLUSTER, PUMA_BENCHMARKS,
                                  simulate_job, synth_key_distribution)

Row = Tuple[str, str, float]
SIZES = ["S", "M", "L"]


def _rii_cluster_loads(num_clusters=240):
    spec = PUMA_BENCHMARKS["RII"]
    counts = synth_key_distribution(spec, 10 * 2 ** 30)
    cids = clustering.cluster_ids_for_keys(
        S._default_hash(np.arange(counts.shape[0])).astype(np.int64),
        num_clusters)
    return clustering.cluster_loads(counts, cids, num_clusters)


def fig01_05_load_balance() -> List[Row]:
    """Fig 1 (hash skew) vs Fig 5 (OS4M balance) on RII_S-class loads."""
    loads = _rii_cluster_loads()
    rows: List[Row] = []
    rows.append(("fig01", "op_load_max_over_min",
                 float(loads.max() / max(loads.min(), 1))))
    h = S.schedule_hash(loads, 30, keys=np.arange(loads.shape[0]))
    o = S.schedule_bss(loads, 30)
    rows.append(("fig01b", "hash_task_max_over_min",
                 float(h.slot_loads.max() / max(h.slot_loads.min(), 1))))
    rows.append(("fig05", "os4m_task_max_over_min",
                 float(o.slot_loads.max() / max(o.slot_loads.min(), 1))))
    return rows


def fig06_maxload() -> List[Row]:
    """max-load / ideal for all 6 benchmarks x 3 sizes, hash vs OS4M."""
    rows: List[Row] = []
    for name, spec in PUMA_BENCHMARKS.items():
        for si, size in enumerate(SIZES):
            counts = synth_key_distribution(
                spec, spec.sizes_gb[si] * 2 ** 30)
            cids = clustering.cluster_ids_for_keys(
                S._default_hash(np.arange(counts.shape[0])).astype(np.int64),
                240)
            loads = clustering.cluster_loads(counts, cids, 240)
            h = S.schedule_hash(loads, 30, keys=np.arange(240))
            o = S.schedule_bss(loads, 30)
            rows.append((f"fig06", f"{name}_{size}_hash", h.balance_ratio))
            rows.append((f"fig06", f"{name}_{size}_os4m", o.balance_ratio))
    return rows


def fig07_08_durations() -> List[Row]:
    """Average Reduce (Fig 7) and Map (Fig 8) task durations."""
    rows: List[Row] = []
    for name in PUMA_BENCHMARKS:
        for size in SIZES:
            h = simulate_job(name, size, "hadoop")
            o = simulate_job(name, size, "os4m")
            rows.append(("fig07", f"{name}_{size}_reduce_hadoop_s",
                         h.avg_reduce_duration))
            rows.append(("fig07", f"{name}_{size}_reduce_os4m_s",
                         o.avg_reduce_duration))
            rows.append(("fig08", f"{name}_{size}_map_hadoop_s",
                         h.avg_map_duration))
            rows.append(("fig08", f"{name}_{size}_map_os4m_s",
                         o.avg_map_duration))
    return rows


def fig09_progress() -> List[Row]:
    """Map wave times for II_S (Fig 2 / Fig 9): Hadoop decelerates."""
    rows: List[Row] = []
    for mode in ("hadoop", "os4m"):
        res = simulate_job("II", "S", mode)
        times = np.diff([t for t, _ in res.map_progress])
        for i, t in enumerate(times):
            rows.append(("fig09", f"{mode}_wave{i + 1}_s", float(t)))
    return rows


def fig10_sched_time() -> List[Row]:
    """Scheduling algorithm runtime (< 0.5 s, ~size-independent)."""
    rows: List[Row] = []
    for name, spec in PUMA_BENCHMARKS.items():
        for si, size in enumerate(SIZES):
            counts = synth_key_distribution(spec, spec.sizes_gb[si] * 2 ** 30)
            cids = clustering.cluster_ids_for_keys(
                S._default_hash(np.arange(counts.shape[0])).astype(np.int64),
                240)
            loads = clustering.cluster_loads(counts, cids, 240)
            t0 = time.perf_counter()
            S.schedule_bss(loads, 30, eta=0.002)
            dt = time.perf_counter() - t0
            rows.append(("fig10", f"{name}_{size}_sched_s", dt))
    return rows


def fig11_network() -> List[Row]:
    """Network overhead of the communication mechanism (exact model)."""
    rows: List[Row] = []
    for name, spec in PUMA_BENCHMARKS.items():
        for si, size in enumerate(SIZES):
            input_bytes = spec.sizes_gb[si] * 2 ** 30
            num_maps = int(np.ceil(input_bytes / PAPER_CLUSTER.block_bytes))
            cost = clustering.network_cost_bytes(num_maps, 240, 8, 30)
            rows.append(("fig11", f"{name}_{size}_collect_mb",
                         cost.collect_total / 2 ** 20))
            rows.append(("fig11", f"{name}_{size}_broadcast_mb",
                         cost.broadcast_total / 2 ** 20))
    return rows


def fig12_13_delays() -> List[Row]:
    """Sort / run delays (Fig 12/13)."""
    rows: List[Row] = []
    for name in PUMA_BENCHMARKS:
        for size in SIZES:
            h = simulate_job(name, size, "hadoop")
            o = simulate_job(name, size, "os4m")
            rows.append(("fig12", f"{name}_{size}_sort_delay_hadoop_s",
                         h.avg_sort_delay))
            rows.append(("fig12", f"{name}_{size}_sort_delay_os4m_s",
                         o.avg_sort_delay))
            rows.append(("fig13", f"{name}_{size}_run_delay_hadoop_s",
                         h.avg_run_delay))
            rows.append(("fig13", f"{name}_{size}_run_delay_os4m_s",
                         o.avg_run_delay))
    return rows


def fig14_job_duration() -> List[Row]:
    """Job duration ratio OS4M / Hadoop (paper: all < 1; best 0.58)."""
    rows: List[Row] = []
    ratios = []
    for name in PUMA_BENCHMARKS:
        for size in SIZES:
            h = simulate_job(name, size, "hadoop")
            o = simulate_job(name, size, "os4m")
            ratio = o.job_duration / h.job_duration
            ratios.append(ratio)
            rows.append(("fig14", f"{name}_{size}_ratio", ratio))
            rows.append(("table4", f"{name}_{size}_hadoop_s", h.job_duration))
    rows.append(("fig14", "best_gain_pct", 100 * (1 - min(ratios))))
    rows.append(("fig14", "worst_gain_pct", 100 * (1 - max(ratios))))
    return rows


def fig15_sensitivity() -> List[Row]:
    """Cluster-count sensitivity (uniform synthetic, paper §5.4)."""
    rows: List[Row] = []
    for n_clusters in [30, 60, 120, 180, 240, 480, 960, 1920]:
        res = simulate_job("II", "S", "os4m", num_clusters=n_clusters)
        rows.append(("fig15", f"n{n_clusters}_reduce_s",
                     res.avg_reduce_duration))
    return rows


def fig16_scaling() -> List[Row]:
    """Node-count scaling (TV, 12 GB): gain largest on few nodes."""
    import dataclasses

    rows: List[Row] = []
    for nodes in [2, 4, 6, 8]:
        cluster = dataclasses.replace(PAPER_CLUSTER, num_nodes=nodes)
        h = simulate_job("TV", "M", "hadoop", cluster=cluster,
                         num_reduce=4 * nodes)
        o = simulate_job("TV", "M", "os4m", cluster=cluster,
                         num_reduce=4 * nodes)
        rows.append(("fig16", f"n{nodes}_gain_pct",
                     100 * (1 - o.job_duration / h.job_duration)))
    return rows


ALL_FIGURES = [
    fig01_05_load_balance, fig06_maxload, fig07_08_durations, fig09_progress,
    fig10_sched_time, fig11_network, fig12_13_delays, fig14_job_duration,
    fig15_sensitivity, fig16_scaling,
]
