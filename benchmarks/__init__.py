# One benchmark per paper figure/table (DESIGN.md §6 experiment index),
# plus the roofline report over the dry-run artifacts and the beyond-paper
# MoE/packing/serving benchmarks.
