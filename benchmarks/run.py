"""Run every benchmark; print ``name,key,value`` CSV.

Usage: PYTHONPATH=src python -m benchmarks.run [--only fig14]
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    sys.path.insert(0, "src")
    from benchmarks.beyond import ALL_BEYOND
    from benchmarks.figures import ALL_FIGURES
    from benchmarks.roofline import summary_rows

    benches = ALL_FIGURES + ALL_BEYOND + [summary_rows]
    print("name,key,value")
    t_start = time.time()
    for fn in benches:
        if args.only and args.only not in fn.__name__:
            continue
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # noqa: BLE001
            print(f"{fn.__name__},ERROR,{type(e).__name__}:{e}",
                  file=sys.stderr)
            raise
        for name, key, value in rows:
            print(f"{name},{key},{value:.6g}")
        print(f"# {fn.__name__}: {time.time() - t0:.1f}s", file=sys.stderr)
    print(f"# total: {time.time() - t_start:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
