"""Run every benchmark; print ``name,key,value`` CSV.

Usage: PYTHONPATH=src python -m benchmarks.run [--only fig14]
       PYTHONPATH=src python -m benchmarks.run --smoke [--out BENCH_schedulers.json]
       PYTHONPATH=src python -m benchmarks.run --smoke-reuse [--out BENCH_schedule_reuse.json]
       PYTHONPATH=src python -m benchmarks.run --smoke-straggler [--out BENCH_stragglers.json]

``--smoke`` is the CI perf-trajectory gate: a small fixed-seed config that
measures (a) the makespan ratio max/ideal of every scheduling strategy and
(b) wall time of the pipelined vs sequential shuffle→reduce engine, and
writes the results to a JSON file benchers can diff across commits.

``--smoke-reuse`` measures the schedule-reuse steady state: one reused-plan
job vs an always-replan job over a stationary batch stream, then under an
injected distribution shift — replan rate, per-batch wall time, stale-vs-
replanned imbalance, and bit-identity of every output.

``--smoke-straggler`` measures the Q||C_max payoff: with one Reduce slot
running at 0.5x (a 2x-slow straggler) on zipf keys, how much estimated
Reduce makespan does speed-*aware* scheduling cut vs speed-*oblivious*
schedules of the same strategy, and does a job detect a mid-run slowdown
online (replan count) while keeping outputs bit-identical.

``--smoke-straggler --measured`` runs the online half on an
8-virtual-device shard_map mesh with **measured** per-device phase-B wave
clocks driving the estimator (the synthetic timing model never runs —
``--slot-slowdown``-style injection scales the measured seconds instead,
standing in for genuinely slow hardware). Same gates; writes
``BENCH_stragglers_measured.json``, and additionally runs the
**overlap-recovery** bench (``BENCH_overlap_measured.json``): the
tick-instrumented measured executor runs the same overlapped pipeline
as unmeasured phase B, so its wall clock must stay within
``OVERLAP_THRESHOLD``× of unmeasured mode — the fenced host-timed
fallback is recorded for context. Needs >= 8 devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``).

``--smoke-sketch`` measures the pluggable-statistics plan path
(docs/STATISTICS.md): wall time of phase A statistics + host pull +
``_plan`` with exact histograms vs a count-min sketch at a large cluster
count (the sketch pulls O(depth × width) cells instead of O(n) columns),
the overflow-replan (escape hatch) rate on a benign and on an engineered
adversarial streaming-prefix workload, and bit-identity of sketch and
prefix outputs against exact statistics; writes ``BENCH_sketch.json``
for the ``sketch`` gate.

``--smoke-shuffle-volume`` measures the coded shuffle
(``shuffle_replication=2`` XOR multicast, docs/SHUFFLE.md): bytes on the
wire uncoded vs coded from the engine's own accounting, bit-identity of
both plain and int8-quantized outputs, and the wall-clock tax; writes
``BENCH_shuffle_volume.json`` for the ``shuffle-volume`` gate.
"""

from __future__ import annotations

import argparse
import json
import statistics
import sys
import time

# Overlap-recovery gate (``--smoke-straggler --measured``): measured-mode
# phase B may cost at most this factor of unmeasured phase B (medians),
# plus an absolute allowance. On CI containers the tick source is the
# CPU *callback* fallback, which pays ~0.5-1.5 ms of host-callback (GIL)
# latency per wave-boundary stamp — slots × (waves+1) ≈ 32 stamps/batch
# here — on 2-core runners; a real device counter pays none of it. The
# absolute slack covers that tax (and the wild phase-B median swings of
# a 2-core box, where 8 virtual devices timeshare the pool); on
# many-core hardware the *ratio* is the meaningful signal. The fenced
# executor's full dispatch+fence per wave is reported alongside for
# context.
OVERLAP_THRESHOLD = 1.6
OVERLAP_ABS_SLACK_S = 0.05

# Coded-shuffle wall-clock gate (``--smoke-shuffle-volume``): the coded
# job may cost at most this factor of the uncoded job, plus an absolute
# allowance. On a CPU-only container the all-to-all "wire" is a memcpy —
# the XOR encode/decode pays pure compute and recovers *zero* network
# time, so the measured ratio here is all coding tax and no coding win;
# on real hardware the saved bytes are the dominant term and the *factor*
# is the meaningful signal. The absolute slack covers the coding compute
# at this bench size (and interpret-mode kernel overhead) the same way
# OVERLAP_ABS_SLACK_S covers the host-callback tax above. The byte
# reduction, by contrast, is measured exactly and gated with no slack.
SHUFFLE_WALL_FACTOR = 1.1
SHUFFLE_WALL_ABS_SLACK_S = 0.35


def bench_smoke(out_path: str) -> dict:
    """Fixed-seed scheduler + engine smoke; writes ``out_path`` JSON."""
    import numpy as np
    import jax.numpy as jnp

    from repro.core import scheduler as S
    from repro.core import simulator as sim
    from repro.core.mapreduce import MapReduceConfig, MapReduceJob

    rng = np.random.default_rng(0)

    # --- (a) schedule quality: max/ideal per strategy on a skewed K.
    loads = rng.zipf(1.3, 480).clip(1, 20_000).astype(float)
    m = 30
    schedulers = {}
    for name in S.AUTO_CANDIDATES:
        fn = S.get_scheduler(name)
        t0 = time.perf_counter()
        sched = fn(loads, m, keys=np.arange(loads.size)) if name == "hash" \
            else fn(loads, m)
        schedulers[name] = {
            "balance_ratio": float(sched.balance_ratio),
            "host_seconds": time.perf_counter() - t0,
        }
    auto_choice, _, auto_costs = sim.pick_strategy(loads, m)

    # --- (b) engine wall time: pipelined vs sequential phase B on the
    # same job (vmap backend; integer-valued floats so the comparison is
    # bit-exact). First call per config includes compilation; measure the
    # steady state with a warmup run.
    slots, K, n = 4, 16384, 96
    keys = (rng.zipf(1.25, size=(slots, K)) % 4099).astype(np.int32)
    vals = np.ones((slots, K, 8), np.float32)
    valid = np.ones((slots, K), bool)
    batch = (jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(valid))

    def make_job(pipelined: bool):
        return MapReduceJob(
            lambda s: s,
            MapReduceConfig(num_slots=slots, num_clusters=n, scheduler="bss",
                            pipelined=pipelined, pipeline_chunks=4),
            backend="vmap")

    jobs = {False: make_job(False), True: make_job(True)}
    results = {p: jobs[p].run(batch) for p in jobs}   # warmup (compile)
    walls = {False: [], True: []}
    for _ in range(12):                # interleaved to de-bias load drift
        for p in (False, True):
            t0 = time.perf_counter()
            results[p] = jobs[p].run(batch)
            walls[p].append(time.perf_counter() - t0)
    t_seq = statistics.median(walls[False])
    t_pipe = statistics.median(walls[True])
    res_seq, res_pipe = results[False], results[True]

    report = {
        "config": {"loads": "zipf(1.3) n=480 m=30",
                   "engine": f"slots={slots} K={K} clusters={n} chunks=4"},
        "schedulers": schedulers,
        "auto_choice": auto_choice,
        "auto_costs": {k: float(v) for k, v in auto_costs.items()},
        "engine": {
            "sequential_seconds": t_seq,
            "pipelined_seconds": t_pipe,
            "speedup": t_seq / max(t_pipe, 1e-12),
            "bit_identical": bool(
                np.array_equal(res_seq.values, res_pipe.values)
                and np.array_equal(res_seq.counts, res_pipe.counts)),
        },
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    return report


def bench_sketch(out_path: str) -> dict:
    """Sketch-vs-exact plan path + escape-hatch rate; writes JSON.

    Fixed seeds, vmap backend. Three measurements:

    * **plan path** — at a large cluster count, median wall time of
      phase A statistics → host pull → ``_plan`` with exact per-cluster
      histograms vs a count-min sketch. The sketch's device→host pull
      and planner input are O(depth × width) regardless of n.
    * **escape-hatch rate** — a benign zipf stream planned from a 25%
      prefix must never trip the overflow hatch; an adversarial stream
      whose hot cluster is absent from the prefix must trip it exactly
      once per batch and still finish with zero overflow.
    * **bit-identity** — sketch and prefix-planned outputs equal the
      exact-statistics outputs on every batch above.
    """
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.core.mapreduce import MapReduceConfig, MapReduceJob

    # --- (a) plan path at large n: stats + pull + host plan.
    slots, K, n = 8, 8192, 1 << 17
    rng = np.random.default_rng(0)
    keys = (rng.zipf(1.2, size=(slots, K)) % n).astype(np.int32)
    vals = np.ones((slots, K, 1), np.float32)
    valid = np.ones((slots, K), bool)
    batch = (jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(valid))

    def make_job(**kw):
        return MapReduceJob(
            lambda s: s,
            MapReduceConfig(num_slots=slots, num_clusters=n, scheduler="lpt",
                            **kw),
            backend="vmap")

    jobs = {"exact": make_job(),
            "sketch": make_job(stats="sketch", sketch_width=1024,
                               sketch_depth=4)}

    def plan_path(job):
        inter, local_k = job._run_sharded(
            lambda s: job._phase_a(s), (0,), ((0, 0, 0), 0), batch,
            cache_key=("a",))
        state = np.asarray(jax.device_get(local_k.reshape(slots, -1)))
        return state, job._plan(state, None, int(inter[0].shape[-1]))

    states, plans = {}, {}
    for name, job in jobs.items():            # warmup (compile)
        states[name], plans[name] = plan_path(job)
    walls = {name: [] for name in jobs}
    for _ in range(9):                 # interleaved to de-bias load drift
        for name, job in jobs.items():
            t0 = time.perf_counter()
            plan_path(job)
            walls[name].append(time.perf_counter() - t0)
    med = {name: statistics.median(w) for name, w in walls.items()}

    # --- (b) + (c): hatch rate and bit-identity on streaming batches.
    slots_b, K_b, n_b, cut = 4, 1024, 64, 1024 // 4

    def stream_batch(seed: int, adversarial: bool):
        brng = np.random.default_rng(seed)
        kk = np.empty((slots_b, K_b), np.int32)
        if adversarial:
            # hot cluster 3 appears only after the planning prefix
            choices = np.array([c for c in range(n_b) if c != 3], np.int32)
            kk[:, :cut] = brng.choice(choices, size=(slots_b, cut))
            kk[:, cut:] = 3
        else:
            kk[:] = (brng.zipf(1.3, size=(slots_b, K_b)) % n_b)
        vv = brng.random((slots_b, K_b, 2)).astype(np.float32)
        return (jnp.asarray(kk), jnp.asarray(vv),
                jnp.ones((slots_b, K_b), bool))

    def make_stream_job(**kw):
        return MapReduceJob(
            lambda s: s,
            MapReduceConfig(num_slots=slots_b, num_clusters=n_b,
                            scheduler="lpt", **kw),
            backend="vmap")

    scenarios = {}
    bit_identical = True
    for scen, adversarial in (("benign", False), ("adversarial", True)):
        batches = [stream_batch(10 * i + int(adversarial), adversarial)
                   for i in range(4)]
        exact_job = make_stream_job()
        prefix_job = make_stream_job(stats="sketch", sketch_width=128,
                                     sketch_depth=4, stream_prefix=0.25)
        overflow_free = True
        for b in batches:
            r_exact = exact_job.run(b)
            r_prefix = prefix_job.run(b)
            bit_identical &= bool(
                np.array_equal(np.asarray(r_exact.values),
                               np.asarray(r_prefix.values))
                and np.array_equal(np.asarray(r_exact.counts),
                                   np.asarray(r_prefix.counts)))
            overflow_free &= (int(r_prefix.overflow) == 0)
        scenarios[scen] = {
            "batches": len(batches),
            "overflow_replans": int(prefix_job.capacity_fallbacks),
            "replan_rate": prefix_job.capacity_fallbacks / len(batches),
            "overflow_free": overflow_free,
        }

    report = {
        "config": {
            "plan_path": f"slots={slots} K={K} clusters={n} lpt "
                         f"sketch=1024x4 backend=vmap",
            "stream": f"slots={slots_b} K={K_b} clusters={n_b} lpt "
                      f"sketch=128x4 stream_prefix=0.25",
        },
        "plan_path": {
            "exact_seconds": med["exact"],
            "sketch_seconds": med["sketch"],
            "speedup": med["exact"] / max(med["sketch"], 1e-12),
            "exact_pull_floats": int(states["exact"].size),
            "sketch_pull_floats": int(states["sketch"].size),
        },
        "scenarios": scenarios,
        "bit_identical": bit_identical,
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    return report


def bench_shuffle_volume(out_path: str) -> dict:
    """Coded-shuffle wire volume: r=2 bytes vs uncoded; writes JSON.

    Fixed seed, balanced keys (uniform over 100k hash values, so the
    per-pair multicast groups are full and the XOR packets carry real
    savings — the regime Coded MapReduce targets). Measures, from the
    engine's own on-device wire accounting (not a model):

    * bytes on the wire uncoded vs coded (gate: ≥ 1.5× reduction) and
      the replica-exchange bytes the coded mode accounts separately;
    * bit-identity of coded vs uncoded outputs (values AND counts);
    * end-to-end wall clock of both modes (gate: coded within
      ``SHUFFLE_WALL_FACTOR``× + ``SHUFFLE_WALL_ABS_SLACK_S`` — see the
      constant's comment for why an absolute allowance exists on CPU);
    * the quantized payload path (int8): coded(q) == uncoded(q) to the
      bit, plus its wire bytes for the trade-off table in docs/SHUFFLE.md.
    """
    import numpy as np
    import jax.numpy as jnp

    from repro.core.mapreduce import MapReduceConfig, MapReduceJob

    slots, K, V, n = 8, 2048, 8, 64
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 100_000, size=(slots, K)).astype(np.int32)
    vals = rng.random((slots, K, V)).astype(np.float32)
    valid = np.ones((slots, K), bool)
    batch = (jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(valid))

    def make_job(replication: int, quantize=None):
        return MapReduceJob(
            lambda s: s,
            MapReduceConfig(num_slots=slots, num_clusters=n,
                            scheduler="os4m", pipelined=False,
                            shuffle_replication=replication,
                            quantize_shuffle=quantize),
            backend="vmap")

    jobs = {1: make_job(1), 2: make_job(2)}
    results = {r: jobs[r].run(batch) for r in jobs}   # warmup (compile)
    walls = {1: [], 2: []}
    for _ in range(8):                 # interleaved to de-bias load drift
        for r in (1, 2):
            t0 = time.perf_counter()
            results[r] = jobs[r].run(batch)
            walls[r].append(time.perf_counter() - t0)
    t_un, t_co = statistics.median(walls[1]), statistics.median(walls[2])
    res_un, res_co = results[1], results[2]

    identical = bool(np.array_equal(res_un.values, res_co.values)
                     and np.array_equal(res_un.counts, res_co.counts))
    reduction = res_un.shuffle_bytes / max(res_co.shuffle_bytes, 1)
    wall_ratio = t_co / max(t_un, 1e-12)
    wall_ok = t_co <= SHUFFLE_WALL_FACTOR * t_un + SHUFFLE_WALL_ABS_SLACK_S

    # quantized payload: coding must stay transparent under int8 too
    q_un = make_job(1, quantize="int8").run(batch)
    q_co = make_job(2, quantize="int8").run(batch)
    q_identical = bool(np.array_equal(q_un.values, q_co.values)
                       and np.array_equal(q_un.counts, q_co.counts))

    report = {
        "config": f"slots={slots} K={K} V={V} clusters={n} "
                  f"backend=vmap scheduler=os4m sequential uniform-keys",
        "uncoded": {"shuffle_bytes": res_un.shuffle_bytes,
                    "shuffle_rows": res_un.shuffle_rows,
                    "shuffle_pairs": res_un.shuffle_pairs,
                    "wall_seconds": t_un},
        "coded": {"shuffle_bytes": res_co.shuffle_bytes,
                  "shuffle_rows": res_co.shuffle_rows,
                  "shuffle_pairs": res_co.shuffle_pairs,
                  "replication_bytes": res_co.replication_bytes,
                  "wall_seconds": t_co},
        "bytes_reduction": float(reduction),
        "bit_identical": identical,
        "wall_ratio": float(wall_ratio),
        "wall_ok": bool(wall_ok),
        "quantized": {
            "uncoded_bytes": q_un.shuffle_bytes,
            "coded_bytes": q_co.shuffle_bytes,
            "bit_identical": q_identical,
            "exact": bool(q_un.quantize_exact),
        },
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    return report


def bench_schedule_reuse(out_path: str) -> dict:
    """Schedule-reuse steady state vs always-replan; writes ``out_path`` JSON.

    Fixed seeds. A stationary phase (10 batches, one zipf law, fresh draws)
    followed by a drifted phase (4 batches, shifted zipf exponent). The
    reuse job should plan exactly once in the stationary phase, replan on
    the shift, and every output must stay bit-identical to the
    always-replan baseline job run on the same batches.
    """
    import numpy as np
    import jax.numpy as jnp

    from repro.core.mapreduce import MapReduceConfig, MapReduceJob
    from repro.core.schedule_cache import ReusePolicy

    slots, K, n = 4, 16384, 96
    stationary, drifted = 10, 4

    def make_batch(seed: int, alpha: float):
        rng = np.random.default_rng(seed)
        keys = (rng.zipf(alpha, size=(slots, K)) % 4099).astype(np.int32)
        vals = np.ones((slots, K, 8), np.float32)
        valid = np.ones((slots, K), bool)
        return (jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(valid))

    batches = [make_batch(i, 1.25) for i in range(stationary)]
    batches += [make_batch(100 + i, 1.5) for i in range(drifted)]

    def make_job(reuse):
        return MapReduceJob(
            lambda s: s,
            MapReduceConfig(num_slots=slots, num_clusters=n, scheduler="auto",
                            pipeline_chunks=4,
                            reuse=ReusePolicy(max_drift=0.15) if reuse else None),
            backend="vmap")

    reuse_job, base_job = make_job(True), make_job(False)

    rows = []
    bit_identical = True
    stale_ratio_at_shift = None
    for i, batch in enumerate(batches):
        if i == stationary:
            # Imbalance a *stale* schedule would suffer on the drifted
            # distribution: evaluate the cached assignment against the
            # fresh key histogram before either job replans.
            snap = reuse_job.schedule_cache.snapshot
            fresh_k = np.asarray(
                np.bincount(np.abs(np.asarray(batch[0]).reshape(-1)) % n,
                            minlength=n), float)
            loads = np.bincount(snap.schedule.assignment, weights=fresh_k,
                                minlength=slots)
            stale_ratio_at_shift = float(loads.max() / (fresh_k.sum() / slots))
        t0 = time.perf_counter()
        r = reuse_job.run(batch)
        t_reuse = time.perf_counter() - t0
        t0 = time.perf_counter()
        b = base_job.run(batch)
        t_base = time.perf_counter() - t0
        bit_identical &= bool(np.array_equal(r.values, b.values)
                              and np.array_equal(r.counts, b.counts))
        rows.append({
            "batch": i, "reused": r.reused, "reason": r.plan_reason,
            "drift": r.drift, "reuse_seconds": t_reuse,
            "replan_seconds": t_base,
            "balance_ratio": float(r.schedule.balance_ratio),
        })

    cache = reuse_job.schedule_cache.stats()
    # Steady state excludes the warmup (compile) batch on both sides.
    steady = [r["reuse_seconds"] for r in rows[1:stationary] if r["reused"]]
    base_steady = [r["replan_seconds"] for r in rows[1:stationary]]
    first_drift = rows[stationary]
    report = {
        "config": {
            "engine": f"slots={slots} K={K} clusters={n} chunks=4 scheduler=auto",
            "policy": "ReusePolicy(max_drift=0.15)",
            "phases": f"{stationary} stationary (zipf 1.25) + {drifted} drifted (zipf 1.5)",
        },
        "replan_rate": cache["replan_rate"],
        "stationary_replans": sum(not r["reused"] for r in rows[:stationary]),
        "drift_replans": sum(not r["reused"] for r in rows[stationary:]),
        "steady_state_seconds": statistics.median(steady) if steady else None,
        "always_replan_seconds": statistics.median(base_steady),
        "speedup": (statistics.median(base_steady) / max(statistics.median(steady), 1e-12)
                    if steady else None),
        "jit_misses": {"reuse_job": reuse_job.jit_misses,
                       "always_replan_job": base_job.jit_misses},
        "drift_at_shift": first_drift["drift"],
        "stale_balance_ratio_at_shift": stale_ratio_at_shift,
        "replanned_balance_ratio_at_shift": first_drift["balance_ratio"],
        "bit_identical": bit_identical,
        "batches": rows,
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    return report


def bench_straggler(out_path: str, measured: bool = False) -> dict:
    """Speed-aware vs speed-oblivious under a 2x-slow slot; writes JSON.

    Fixed seeds. Part (a): schedule quality — zipf cluster loads, one slot
    at 0.5x relative speed; each strategy plans once ignoring speeds
    (P||C_max, the pre-refactor behaviour) and once with the true speed
    vector (Q||C_max), and both schedules are priced by the simulator's
    flow-shop model *under the true speeds*. Part (b): the online loop —
    a reuse-policy job with speed estimation serves a stationary stream,
    slot 1 turns 2x slow mid-run (``set_slot_slowdown(1, 2.0)`` — the
    factor is a wall-clock multiplier); the job must detect it from wave
    timings, replan (``speed_drift``), and keep every output bit-identical
    to a speed-oblivious job on the same batches.

    ``measured=True`` runs part (b) on an 8-virtual-device shard_map mesh
    with measured per-device wave clocks feeding the estimator (the
    synthetic model never runs; the slowdown is injected into the
    *measured* seconds).
    """
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.core import scheduler as S
    from repro.core import simulator as sim
    from repro.core.mapreduce import MapReduceConfig, MapReduceJob
    from repro.core.schedule_cache import ReusePolicy

    rng = np.random.default_rng(0)

    # --- (a) estimated Reduce makespan, oblivious vs aware, one 2x-slow slot.
    loads = rng.zipf(1.3, 480).clip(1, 20_000).astype(float)
    m = 8
    speeds = np.ones(m)
    speeds[3] = 0.5
    strategies = {}
    for name in ("lpt", "multifit", "bss"):
        fn = S.get_scheduler(name)
        oblivious = fn(loads, m)                 # plans blind to the straggler
        aware = fn(loads, m, speeds=speeds)      # plans around it
        t_obl = sim.estimate_reduce_time(loads, oblivious, speeds=speeds)
        t_aware = sim.estimate_reduce_time(loads, aware, speeds=speeds)
        strategies[name] = {
            "oblivious_makespan_s": float(t_obl),
            "aware_makespan_s": float(t_aware),
            "makespan_cut": float(1.0 - t_aware / t_obl),
            "aware_finish_ratio": float(aware.finish_ratio),
        }
    hash_sched = S.schedule_hash(loads, m, keys=np.arange(loads.size),
                                 speeds=speeds)
    hash_makespan = sim.estimate_reduce_time(loads, hash_sched, speeds=speeds)

    # --- (b) mid-run slowdown: online detection, replans, bit-identity.
    if measured:
        slots, K, n = 8, 4096, 96
        total_batches, slow_at = 10, 3
        if len(jax.devices()) < slots:
            sys.exit(f"--measured needs >= {slots} devices (set XLA_FLAGS="
                     f"--xla_force_host_platform_device_count={slots})")
        from jax.sharding import Mesh

        mesh = Mesh(np.asarray(jax.devices()[:slots]), ("mr_slots",))
        backend = "shard_map"
    else:
        slots, K, n = 4, 8192, 96
        total_batches, slow_at = 8, 3
        mesh, backend = None, "vmap"

    def make_batch(seed: int):
        brng = np.random.default_rng(seed)
        keys = (brng.zipf(1.25, size=(slots, K)) % 4099).astype(np.int32)
        vals = np.ones((slots, K, 8), np.float32)
        valid = np.ones((slots, K), bool)
        return (jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(valid))

    batches = [make_batch(i) for i in range(total_batches)]
    aware_job = MapReduceJob(
        lambda s: s,
        MapReduceConfig(num_slots=slots, num_clusters=n, scheduler="bss",
                        estimate_speeds=True,
                        reuse=ReusePolicy(max_drift=0.15,
                                          max_speed_drift=0.25)),
        backend=backend, mesh=mesh)
    oblivious_job = MapReduceJob(
        lambda s: s,
        MapReduceConfig(num_slots=slots, num_clusters=n, scheduler="bss"),
        backend="vmap")

    rows = []
    bit_identical = True
    measured_batches = 0
    for i, batch in enumerate(batches):
        if i == slow_at:
            aware_job.set_slot_slowdown(1, 2.0)   # 2x wall-clock = 0.5x speed
        r = aware_job.run(batch)
        b = oblivious_job.run(batch)
        bit_identical &= bool(np.array_equal(np.asarray(r.values),
                                             np.asarray(b.values))
                              and np.array_equal(np.asarray(r.counts),
                                                 np.asarray(b.counts)))
        t = aware_job.last_wave_timings
        if t is not None and t.valid:
            measured_batches += 1
        rows.append({
            "batch": i, "reused": r.reused, "reason": r.plan_reason,
            "speed_drift": (None if r.speed_drift is None
                            else min(float(r.speed_drift), 1e9)),
            "slot_speeds": [round(float(s), 4) for s in r.slot_speeds],
            "wave_seconds": (None if t is None else
                             [round(float(s), 5) for s in t.slot_seconds()]),
        })
    cache = aware_job.schedule_cache.stats()

    report = {
        "config": {
            "schedule": f"zipf(1.3) n=480 m={m}, slot 3 at 0.5x speed",
            "engine": (f"slots={slots} K={K} clusters={n} bss "
                       f"backend={backend}, slot 1 -> 2x slowdown at batch "
                       f"{slow_at}"),
        },
        "timing_source": ("measured per-device wave clocks" if measured
                          else "synthetic work/slowdown model"),
        "measured_batches": measured_batches,
        "strategies": strategies,
        "hash_makespan_s": float(hash_makespan),
        "min_makespan_cut": min(s["makespan_cut"] for s in strategies.values()),
        "speed_replans": cache["speed_replans"],
        "replans": cache["replans"],
        "estimated_final_speeds": [
            round(float(s), 4) for s in aware_job.speed_estimator.speeds()
        ],
        "bit_identical": bit_identical,
        "batches": rows,
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    return report


def bench_overlap_measured(out_path: str) -> dict:
    """Overlap recovery of tick-instrumented measured mode; writes JSON.

    One plan is built once (phase A + host schedule, off the clock), then
    three phase-B executors replay it on the same intermediate data:

    * ``unmeasured``       — the fused overlapped pipeline (``_execute``);
    * ``measured_ticks``   — the SAME overlapped pipeline with on-device
      wave tick stamps + host readback of the tiny ticks buffer
      (``_execute_measured``), the ISSUE 5 tentpole path;
    * ``measured_fenced``  — the host-fenced fallback
      (``_execute_measured_fenced``), one dispatch + fence per wave —
      recorded for context, not gated (it is exactly the overlap loss
      the tick path exists to avoid).

    Gate: median ``measured_ticks`` wall ≤ ``OVERLAP_THRESHOLD`` ×
    median ``unmeasured`` + ``OVERLAP_ABS_SLACK_S``.
    """
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.core.mapreduce import MapReduceConfig, MapReduceJob

    slots, K, n, chunks = 8, 4096, 96, 4
    if len(jax.devices()) < slots:
        sys.exit(f"overlap bench needs >= {slots} devices (set XLA_FLAGS="
                 f"--xla_force_host_platform_device_count={slots})")
    from jax.sharding import Mesh

    mesh = Mesh(np.asarray(jax.devices()[:slots]), ("mr_slots",))
    job = MapReduceJob(
        lambda s: s,
        MapReduceConfig(num_slots=slots, num_clusters=n, scheduler="bss",
                        pipeline_chunks=chunks, estimate_speeds=True),
        backend="shard_map", mesh=mesh)

    rng = np.random.default_rng(0)
    keys = (rng.zipf(1.25, size=(slots, K)) % 4099).astype(np.int32)
    batch = (jnp.asarray(keys),
             jnp.asarray(np.ones((slots, K, 8), np.float32)),
             jnp.asarray(np.ones((slots, K), bool)))

    # Phase A + one host plan, shared by every executor (off the clock).
    inter, local_k = job._run_sharded(
        lambda s: job._phase_a(s), (0,), ((0, 0, 0), 0), batch,
        cache_key=("a",))
    local_hist = np.asarray(jax.device_get(local_k.reshape(slots, n)))
    planned = job._plan(local_hist, local_hist.sum(axis=0),
                        int(inter[0].shape[-1]))

    execs = {
        "unmeasured": lambda: job._execute(inter, planned),
        "measured_ticks": lambda: job._execute_measured(inter, planned),
        "measured_fenced": lambda: job._execute_measured_fenced(inter, planned),
    }
    for fn in execs.values():                  # warmup (compile)
        jax.block_until_ready(fn()[:3])
    walls = {name: [] for name in execs}
    for _ in range(13):                        # interleaved to de-bias drift
        for name, fn in execs.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn()[:3])
            walls[name].append(time.perf_counter() - t0)
    med = {name: statistics.median(w) for name, w in walls.items()}
    ratio = med["measured_ticks"] / max(med["unmeasured"], 1e-12)
    report = {
        "config": f"slots={slots} K={K} clusters={n} chunks={chunks} "
                  f"backend=shard_map",
        "phase_b_seconds": med,
        "measured_over_unmeasured": ratio,
        "fenced_over_unmeasured":
            med["measured_fenced"] / max(med["unmeasured"], 1e-12),
        "threshold": OVERLAP_THRESHOLD,
        "abs_slack_seconds": OVERLAP_ABS_SLACK_S,
        "overlap_recovered": bool(
            med["measured_ticks"]
            <= OVERLAP_THRESHOLD * med["unmeasured"] + OVERLAP_ABS_SLACK_S),
        "walls": walls,
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    return report


def bench_elastic(out_path: str) -> dict:
    """Elastic-mesh fault injection sweep; writes ``out_path`` JSON.

    Fixed seeds, vmap backend. Four scenarios against one uninterrupted
    8-slot baseline:

    * ``dead_at_start`` — slot 5 declared dead before the batch
      (``set_slot_slowdown(5, 0)``): outputs bit-identical, the plan
      assigns the dead slot zero load.
    * ``die_mid_wave`` — slot 3 killed just before wave 2 of 4
      (``set_slot_failure(3, at_wave=2)`` under ``checkpoint_waves``):
      outputs bit-identical, only the unfinished waves replay
      (``replayed ≤ waves − checkpoint``), and the recovery plan assigns
      the dead slot nothing.
    * ``resize_8to6`` / ``resize_6to8`` — a warm reuse-policy job is
      resized; the cached snapshot is re-projected (re-binned ``K^(i)``
      + one host re-plan), so the next batch replays it instead of going
      cold (``plan_reason`` must not be ``"cold"``), and outputs match a
      dedicated fixed-size job.
    """
    import numpy as np
    import jax.numpy as jnp

    from repro.core.mapreduce import MapReduceConfig, MapReduceJob
    from repro.core.schedule_cache import ReusePolicy

    slots, K, n, chunks = 8, 4096, 96, 4

    def make_batch(num_slots: int, seed: int = 0):
        brng = np.random.default_rng(seed)
        keys = (brng.zipf(1.25, size=(num_slots, K)) % 4099).astype(np.int32)
        vals = np.ones((num_slots, K, 8), np.float32)
        valid = np.ones((num_slots, K), bool)
        return (jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(valid))

    def make_job(num_slots: int, checkpoint: bool = False, reuse=None):
        return MapReduceJob(
            lambda s: s,
            MapReduceConfig(num_slots=num_slots, num_clusters=n,
                            scheduler="bss", pipeline_chunks=chunks,
                            checkpoint_waves=checkpoint, reuse=reuse),
            backend="vmap")

    batch8 = make_batch(slots)
    batch6 = make_batch(6)
    base8 = make_job(slots).run(batch8)
    base6 = make_job(6).run(batch6)

    def identical(a, b):
        return bool(np.array_equal(a.values, b.values)
                    and np.array_equal(a.counts, b.counts))

    # --- dead at start: plan around the corpse, outputs unchanged.
    dead_job = make_job(slots, checkpoint=True)
    dead_job.set_slot_slowdown(5, 0.0)            # 0 = dead, not slow
    r_dead = dead_job.run(batch8)
    dead_start = {
        "bit_identical": identical(base8, r_dead),
        "dead_slot_load": float(r_dead.schedule.slot_loads[5]),
        "events": list(dead_job.mesh_events),
    }

    # --- die mid-wave: checkpoint + bounded replay onto the survivors.
    kill_job = make_job(slots, checkpoint=True)
    kill_at = 2
    kill_job.set_slot_failure(3, at_wave=kill_at)
    r_kill = kill_job.run(batch8)
    num_waves = int(kill_job.last_checkpoint.num_chunks)
    replay_plan = kill_job.last_replay_plan
    mid_kill = {
        "bit_identical": identical(base8, r_kill),
        "num_waves": num_waves,
        "checkpoint_wave": int(kill_job.last_checkpoint_wave),
        "replayed_waves": int(kill_job.last_replayed_waves),
        "replay_bound_ok": bool(
            kill_job.last_replayed_waves
            <= num_waves - kill_job.last_checkpoint_wave),
        "replay_dead_slot_load": (
            None if replay_plan is None
            else float(replay_plan.schedule.slot_loads[3])),
        "events": list(kill_job.mesh_events),
    }

    # --- warm resizes: the snapshot re-projects instead of going cold.
    policy = ReusePolicy(max_drift=0.35, revalidate_every=1)
    elastic_job = make_job(slots, reuse=policy)
    elastic_job.run(batch8)                       # cold plan
    r_warm = elastic_job.run(batch8)              # warm reuse
    elastic_job.resize(6)
    r_6 = elastic_job.run(batch6)
    elastic_job.resize(8)
    r_8 = elastic_job.run(batch8)
    resizes = {
        "warm_reason": r_warm.plan_reason,
        "after_8to6_reason": r_6.plan_reason,
        "after_6to8_reason": r_8.plan_reason,
        "no_cold_after_resize": bool(r_6.plan_reason != "cold"
                                     and r_8.plan_reason != "cold"),
        "reprojections": int(elastic_job.schedule_cache.reprojections),
        "outputs_6_match": bool(np.allclose(r_6.values, base6.values)
                                and np.array_equal(r_6.counts, base6.counts)),
        "outputs_8_bit_identical": identical(base8, r_8),
        "events": list(elastic_job.mesh_events),
    }

    report = {
        "config": f"slots={slots} K={K} clusters={n} chunks={chunks} "
                  f"backend=vmap scheduler=bss",
        "dead_at_start": dead_start,
        "die_mid_wave": mid_kill,
        "resizes": resizes,
        "bit_identical": bool(dead_start["bit_identical"]
                              and mid_kill["bit_identical"]
                              and resizes["outputs_8_bit_identical"]),
        "dead_load_total": float(
            dead_start["dead_slot_load"]
            + (mid_kill["replay_dead_slot_load"] or 0.0)),
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    return report


def bench_multijob(out_path: str) -> dict:
    """Multi-job ΣwᵢCᵢ bench: WSPT admission vs FIFO on a skewed 2-job mix.

    Fixed seeds, vmap backend, 8 slots. Job ``bulk`` holds 6 pending
    batches at weight 1; job ``urgent`` holds 1 batch of the same shape
    at weight 4 — the classic case where FIFO (bulk arrived first) is
    maximally wrong and Smith's rule is exactly optimal. Both
    coordinators run the identical workload end to end after an untimed
    warm-up batch per job (excludes jit compile *and* the cold plan from
    the measured completions). Reported:

    * ``improvement`` — 1 − ΣwC(wspt) / ΣwC(fifo), gated ≥ 20%;
    * ``bit_identical`` — every coordinator-run batch output equals the
      same batch run on a solo job (scheduling moves *where* work runs,
      never what it computes), gated;
    * ``cache.collisions`` — tenant pairs sharing snapshot state, gated
      == 0 (multi-tenant isolation is measured, not assumed);
    * ``coschedule_overlap`` — cross-job fraction of the merged §4.4
      wave issue order (telemetry).
    """
    import numpy as np
    import jax.numpy as jnp

    from repro.core.mapreduce import MapReduceConfig, MapReduceJob
    from repro.core.multi_job import MultiJobCoordinator
    from repro.core.schedule_cache import ReusePolicy

    slots, K, n, chunks = 8, 1024, 64, 4
    BULK_BATCHES, URGENT_BATCHES = 6, 1
    W_BULK, W_URGENT = 1.0, 4.0

    def make_batch(seed: int):
        brng = np.random.default_rng(seed)
        keys = (brng.zipf(1.25, size=(slots, K)) % 997).astype(np.int32)
        vals = np.ones((slots, K, 8), np.float32)
        return (jnp.asarray(keys), jnp.asarray(vals),
                jnp.ones((slots, K), bool))

    def make_job():
        return MapReduceJob(
            lambda s: s,
            MapReduceConfig(num_slots=slots, num_clusters=n,
                            scheduler="bss", pipeline_chunks=chunks,
                            reuse=ReusePolicy(max_drift=0.5)),
            backend="vmap")

    bulk_batches = [make_batch(s) for s in range(BULK_BATCHES)]
    urgent_batches = [make_batch(100 + s) for s in range(URGENT_BATCHES)]
    warm_batch = make_batch(999)

    # Solo references for the bit-identity check (same warm-up sequence).
    solo = {}
    for name, batches in (("bulk", bulk_batches), ("urgent", urgent_batches)):
        job = make_job()
        job.run(warm_batch)
        solo[name] = [job.run(b) for b in batches]

    def run_order(order: str) -> dict:
        co = MultiJobCoordinator(num_slots=slots)
        for name, weight in (("bulk", W_BULK), ("urgent", W_URGENT)):
            handle = co.add_job(name, make_job(), weight=weight)
            handle.job.run(warm_batch)   # untimed: compile + cold plan
        for b in bulk_batches:
            co.submit("bulk", b)
        for b in urgent_batches:
            co.submit("urgent", b)
        out = co.run_queue(order=order)
        out["results"] = {name: co[name].results
                          for name in ("bulk", "urgent")}
        return out

    fifo = run_order("fifo")
    wspt = run_order("wspt")

    identical = True
    for name in ("bulk", "urgent"):
        for run in (fifo, wspt):
            for ref, got in zip(solo[name], run["results"][name]):
                identical = identical and bool(
                    np.array_equal(ref.values, got.values)
                    and np.array_equal(ref.counts, got.counts))

    wc_fifo = fifo["weighted_completion"]
    wc_wspt = wspt["weighted_completion"]
    report = {
        "config": f"slots={slots} K={K} clusters={n} chunks={chunks} "
                  f"backend=vmap scheduler=bss "
                  f"bulk={BULK_BATCHES}x@w{W_BULK:g} "
                  f"urgent={URGENT_BATCHES}x@w{W_URGENT:g}",
        "fifo": {"order": fifo["order"],
                 "completions_s": fifo["completions"],
                 "weighted_completion_s": wc_fifo},
        "wspt": {"order": wspt["order"],
                 "completions_s": wspt["completions"],
                 "weighted_completion_s": wc_wspt},
        "improvement": 1.0 - wc_wspt / wc_fifo if wc_fifo > 0 else 0.0,
        "bit_identical": identical,
        "coschedule_overlap": wspt["coschedule_overlap"],
        "cache": {k: v for k, v in wspt["cache"].items()
                  if k != "per_tenant"},
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="run the CI bench-smoke and write --out JSON")
    ap.add_argument("--smoke-reuse", action="store_true",
                    help="run the schedule-reuse bench and write --out JSON")
    ap.add_argument("--smoke-straggler", action="store_true",
                    help="run the Q||C_max straggler bench and write --out JSON")
    ap.add_argument("--measured", action="store_true",
                    help="with --smoke-straggler: shard_map mesh + measured "
                         "per-device wave timings (needs >= 8 devices)")
    ap.add_argument("--smoke-elastic", action="store_true",
                    help="run the elastic-mesh fault-injection bench and "
                         "write --out JSON")
    ap.add_argument("--smoke-multijob", action="store_true",
                    help="run the multi-job ΣwC admission bench and "
                         "write --out JSON")
    ap.add_argument("--smoke-shuffle-volume", action="store_true",
                    help="run the coded-shuffle wire-volume bench and "
                         "write --out JSON")
    ap.add_argument("--smoke-sketch", action="store_true",
                    help="run the sketch-statistics plan-path bench and "
                         "write --out JSON")
    ap.add_argument("--out", default="BENCH_schedulers.json")
    args = ap.parse_args()

    if args.smoke_sketch:
        sys.path.insert(0, "src")
        out = args.out if args.out != "BENCH_schedulers.json" \
            else "BENCH_sketch.json"
        report = bench_sketch(out)
        pp = report["plan_path"]
        print(f"plan path: exact={pp['exact_seconds'] * 1e3:.1f}ms "
              f"sketch={pp['sketch_seconds'] * 1e3:.1f}ms "
              f"speedup={pp['speedup']:.2f}x "
              f"(pull {pp['exact_pull_floats']} -> "
              f"{pp['sketch_pull_floats']} floats)")
        for scen, row in report["scenarios"].items():
            print(f"{scen}: overflow_replans={row['overflow_replans']}"
                  f"/{row['batches']} overflow_free={row['overflow_free']}")
        print(f"bit_identical={report['bit_identical']}")
        # thresholds live in benchmarks/check.py (--gate sketch); keep
        # the runner's own exit status honest for local use too
        if not report["bit_identical"]:
            sys.exit("FAIL: sketch/prefix outputs diverged from exact")
        if report["scenarios"]["benign"]["overflow_replans"] != 0:
            sys.exit("FAIL: benign stream tripped the overflow hatch")
        if report["scenarios"]["adversarial"]["overflow_replans"] < 1:
            sys.exit("FAIL: adversarial stream never exercised the hatch")
        return

    if args.smoke_shuffle_volume:
        sys.path.insert(0, "src")
        out = args.out if args.out != "BENCH_schedulers.json" \
            else "BENCH_shuffle_volume.json"
        report = bench_shuffle_volume(out)
        un, co = report["uncoded"], report["coded"]
        print(f"uncoded: {un['shuffle_bytes']} B on the wire "
              f"({un['shuffle_rows']} rows, {un['shuffle_pairs']} pairs) "
              f"wall={un['wall_seconds'] * 1e3:.1f}ms")
        print(f"coded:   {co['shuffle_bytes']} B on the wire "
              f"({co['shuffle_rows']} rows) + {co['replication_bytes']} B "
              f"replica exchange wall={co['wall_seconds'] * 1e3:.1f}ms")
        print(f"reduction={report['bytes_reduction']:.2f}x "
              f"bit_identical={report['bit_identical']} "
              f"wall_ratio={report['wall_ratio']:.2f} "
              f"(wall_ok={report['wall_ok']})")
        q = report["quantized"]
        print(f"int8: uncoded={q['uncoded_bytes']} B "
              f"coded={q['coded_bytes']} B "
              f"bit_identical={q['bit_identical']}")
        # thresholds live in benchmarks/check.py (--gate shuffle-volume);
        # keep the runner's own exit status honest for local use too
        if not report["bit_identical"]:
            sys.exit("FAIL: coded outputs diverged from uncoded")
        if report["bytes_reduction"] < 1.5:
            sys.exit("FAIL: coded shuffle cut wire bytes by only "
                     f"{report['bytes_reduction']:.2f}x (< 1.5x)")
        if not report["wall_ok"]:
            sys.exit("FAIL: coded wall clock "
                     f"x{report['wall_ratio']:.2f} exceeds "
                     f"{SHUFFLE_WALL_FACTOR}x uncoded + "
                     f"{SHUFFLE_WALL_ABS_SLACK_S * 1e3:.0f}ms")
        return

    if args.smoke_multijob:
        sys.path.insert(0, "src")
        out = args.out if args.out != "BENCH_schedulers.json" \
            else "BENCH_multijob.json"
        report = bench_multijob(out)
        print(f"fifo:  order={report['fifo']['order']} "
              f"ΣwC={report['fifo']['weighted_completion_s']:.3f}s")
        print(f"wspt:  order={report['wspt']['order']} "
              f"ΣwC={report['wspt']['weighted_completion_s']:.3f}s")
        print(f"improvement={report['improvement'] * 100:.1f}% "
              f"bit_identical={report['bit_identical']} "
              f"collisions={report['cache']['collisions']} "
              f"overlap={report['coschedule_overlap']:.2f}")
        # thresholds live in benchmarks/check.py (--gate multijob); keep
        # the runner's own exit status honest for local use too
        if not report["bit_identical"]:
            sys.exit("FAIL: a coordinator-run batch diverged from its "
                     "solo-job output")
        if report["improvement"] < 0.20:
            sys.exit("FAIL: WSPT admission improved ΣwC by only "
                     f"{report['improvement'] * 100:.1f}% (< 20%)")
        if report["cache"]["collisions"] != 0:
            sys.exit("FAIL: tenant schedule caches shared snapshot state")
        return

    if args.smoke_elastic:
        sys.path.insert(0, "src")
        out = args.out if args.out != "BENCH_schedulers.json" \
            else "BENCH_elastic.json"
        report = bench_elastic(out)
        mk = report["die_mid_wave"]
        rs = report["resizes"]
        print(f"dead_at_start: bit_identical="
              f"{report['dead_at_start']['bit_identical']} "
              f"dead_slot_load={report['dead_at_start']['dead_slot_load']}")
        print(f"die_mid_wave: bit_identical={mk['bit_identical']} "
              f"ckpt={mk['checkpoint_wave']}/{mk['num_waves']} "
              f"replayed={mk['replayed_waves']} "
              f"replay_dead_load={mk['replay_dead_slot_load']}")
        print(f"resizes: 8to6={rs['after_8to6_reason']} "
              f"6to8={rs['after_6to8_reason']} "
              f"reprojections={rs['reprojections']} "
              f"6_match={rs['outputs_6_match']} "
              f"8_identical={rs['outputs_8_bit_identical']}")
        # thresholds live in benchmarks/check.py (--gate elastic); keep
        # the runner's own exit status honest for local use too
        if not report["bit_identical"]:
            sys.exit("FAIL: a fault scenario diverged from the "
                     "uninterrupted baseline")
        if report["dead_load_total"] != 0.0:
            sys.exit("FAIL: a plan assigned work to a dead slot")
        return

    if args.smoke_straggler:
        sys.path.insert(0, "src")
        out = args.out if args.out != "BENCH_schedulers.json" \
            else ("BENCH_stragglers_measured.json" if args.measured
                  else "BENCH_stragglers.json")
        report = bench_straggler(out, measured=args.measured)
        print(f"timing source: {report['timing_source']} "
              f"({report['measured_batches']} measured batches)")
        for name, row in report["strategies"].items():
            print(f"{name}: oblivious={row['oblivious_makespan_s']:.1f}s "
                  f"aware={row['aware_makespan_s']:.1f}s "
                  f"cut={row['makespan_cut'] * 100:.1f}% "
                  f"finish_ratio={row['aware_finish_ratio']:.3f}")
        print(f"hash baseline: {report['hash_makespan_s']:.1f}s")
        print(f"mid-run slowdown: {report['speed_replans']} speed replans, "
              f"estimated speeds {report['estimated_final_speeds']}, "
              f"bit_identical={report['bit_identical']}")
        if not report["bit_identical"]:
            sys.exit("FAIL: speed-aware outputs diverged from speed-oblivious")
        if report["min_makespan_cut"] < 0.25:
            sys.exit("FAIL: speed-aware scheduling cut makespan by only "
                     f"{report['min_makespan_cut'] * 100:.1f}% (< 25%)")
        if report["speed_replans"] < 1:
            sys.exit("FAIL: mid-run slowdown did not trigger a speed replan")
        if args.measured and report["measured_batches"] < 1:
            sys.exit("FAIL: no batch delivered valid measured timings")
        if args.measured:
            ov = bench_overlap_measured("BENCH_overlap_measured.json")
            med = ov["phase_b_seconds"]
            print(f"overlap: unmeasured={med['unmeasured'] * 1e3:.1f}ms "
                  f"ticks={med['measured_ticks'] * 1e3:.1f}ms "
                  f"(x{ov['measured_over_unmeasured']:.2f}) "
                  f"fenced={med['measured_fenced'] * 1e3:.1f}ms "
                  f"(x{ov['fenced_over_unmeasured']:.2f})")
            if not ov["overlap_recovered"]:
                sys.exit("FAIL: measured-mode phase B lost the overlap "
                         f"(x{ov['measured_over_unmeasured']:.2f} > "
                         f"{OVERLAP_THRESHOLD} of unmeasured + "
                         f"{OVERLAP_ABS_SLACK_S * 1e3:.0f}ms)")
        return

    if args.smoke_reuse:
        sys.path.insert(0, "src")
        out = args.out if args.out != "BENCH_schedulers.json" \
            else "BENCH_schedule_reuse.json"
        report = bench_schedule_reuse(out)
        print(f"replan_rate={report['replan_rate']:.3f} "
              f"(stationary replans={report['stationary_replans']}, "
              f"drift replans={report['drift_replans']})")
        if report["steady_state_seconds"] is not None:
            print(f"steady_state={report['steady_state_seconds'] * 1e3:.1f} ms/batch "
                  f"always_replan={report['always_replan_seconds'] * 1e3:.1f} ms/batch "
                  f"speedup={report['speedup']:.2f}x")
        print(f"imbalance at shift: stale="
              f"{report['stale_balance_ratio_at_shift']:.3f} "
              f"replanned={report['replanned_balance_ratio_at_shift']:.3f}")
        print(f"bit_identical={report['bit_identical']}")
        if not report["bit_identical"]:
            sys.exit("FAIL: reused-schedule outputs diverged from always-replan")
        if report["stationary_replans"] != 1:
            sys.exit("FAIL: stationary phase should plan exactly once, got "
                     f"{report['stationary_replans']}")
        return

    if args.smoke:
        sys.path.insert(0, "src")
        report = bench_smoke(args.out)
        eng = report["engine"]
        print(f"auto_choice={report['auto_choice']}")
        for name, row in report["schedulers"].items():
            print(f"{name}: balance_ratio={row['balance_ratio']:.4f}")
        print(f"engine: sequential={eng['sequential_seconds']:.3f}s "
              f"pipelined={eng['pipelined_seconds']:.3f}s "
              f"bit_identical={eng['bit_identical']}")
        if not eng["bit_identical"]:
            sys.exit("FAIL: pipelined engine diverged from sequential")
        return

    sys.path.insert(0, "src")
    from benchmarks.beyond import ALL_BEYOND
    from benchmarks.figures import ALL_FIGURES
    from benchmarks.roofline import summary_rows

    benches = ALL_FIGURES + ALL_BEYOND + [summary_rows]
    print("name,key,value")
    t_start = time.time()
    for fn in benches:
        if args.only and args.only not in fn.__name__:
            continue
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # noqa: BLE001
            print(f"{fn.__name__},ERROR,{type(e).__name__}:{e}",
                  file=sys.stderr)
            raise
        for name, key, value in rows:
            print(f"{name},{key},{value:.6g}")
        print(f"# {fn.__name__}: {time.time() - t0:.1f}s", file=sys.stderr)
    print(f"# total: {time.time() - t_start:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
